//! First-class traffic classes (router/scheduler QoS): the SLO a request
//! is served under is no longer an anonymous `(ttft_slo, tpot_slo)`
//! scalar pair re-plumbed ad hoc by every metrics/autoscaler/harness
//! call site — it is a [`TrafficClass`] a request carries by
//! [`ClassId`], declared once in `ServingConfig::classes` and threaded
//! end-to-end:
//!
//! * `workload::with_class_mix` tags deterministic mixed-class traces,
//! * the `Scheduler` admits higher-priority classes first and preempts
//!   the lowest-priority running sequence first,
//! * the `Router` penalizes placing high-priority traffic on replicas
//!   whose recent per-class attainment is degraded,
//! * `MetricsCollector` filters compliance per request against *its own
//!   class's* SLO (one shared helper — no more triplicated filters),
//! * the `Autoscaler` scales against weighted per-class attainment.
//!
//! The class machinery is inert at uniform priority: priority-0 classes
//! never reorder admission, never change preemption victims and never
//! move a routing score, so a single default class
//! ([`TrafficClass::default_class`], priority 0, weight 1) behaves
//! exactly like the pre-refactor anonymous-SLO configuration
//! (`repro run qos-sweep` carries the EqExact-0 parity claim — tagged
//! uniform-priority runs bitwise-equal untagged ones, and the class
//! metrics bitwise-equal the deleted scalar formulas;
//! `rust/tests/proptests.rs` carries the property over random
//! workloads).

use crate::serving::metrics::RequestMetrics;
use crate::util::json::Json;

/// Index of a request's traffic class inside `ServingConfig::classes`
/// (and everywhere a [`ClassSet`] flows). Class 0 is always the default.
pub type ClassId = usize;

/// One traffic class: a named latency contract plus its scheduling
/// priority and goodput weight.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficClass {
    /// Stable name (JSON tag, report row label).
    pub name: String,
    /// Scheduling priority: higher classes are admitted first under
    /// watermark pressure and preempted last. Priority 0 is the legacy
    /// class-blind behavior (FIFO admission, youngest-first preemption,
    /// no routing penalty) — by construction, so a uniform-priority-0
    /// class set replays the pre-refactor path bitwise.
    pub priority: u8,
    /// TTFT service-level objective in seconds.
    pub ttft_slo: f64,
    /// TPOT service-level objective in seconds.
    pub tpot_slo: f64,
    /// Weight of this class in fleet-level weighted attainment (the
    /// autoscaler's control signal) — interactive traffic typically
    /// outweighs background batches.
    pub weight: f64,
}

impl TrafficClass {
    pub fn new(
        name: impl Into<String>,
        priority: u8,
        ttft_slo: f64,
        tpot_slo: f64,
        weight: f64,
    ) -> TrafficClass {
        let c = TrafficClass { name: name.into(), priority, ttft_slo, tpot_slo, weight };
        c.validate().expect("valid traffic class");
        c
    }

    /// The class every untagged request belongs to: priority 0, weight 1,
    /// and the SLO the pre-refactor scalar call sites defaulted to
    /// (TTFT <= 1 s, TPOT <= 0.1 s). A config whose `classes` is exactly
    /// `[default_class()]` reproduces the legacy behavior bitwise.
    pub fn default_class() -> TrafficClass {
        TrafficClass::new("default", 0, 1.0, 0.1, 1.0)
    }

    /// Back-compat shim: an anonymous priority-0 class carrying a bare
    /// scalar SLO pair — the ONLY place raw `(ttft_slo, tpot_slo)`
    /// scalars should enter the class system from.
    pub fn scalar(ttft_slo: f64, tpot_slo: f64) -> TrafficClass {
        TrafficClass::new("slo", 0, ttft_slo, tpot_slo, 1.0)
    }

    /// Preset: interactive chat — tight TTFT/TPOT, top priority, heavy
    /// goodput weight.
    pub fn interactive() -> TrafficClass {
        TrafficClass::new("interactive", 2, 0.5, 0.05, 4.0)
    }

    /// Preset: batch summarization — relaxed latency, mid priority.
    pub fn batch() -> TrafficClass {
        TrafficClass::new("batch", 1, 2.0, 0.2, 1.0)
    }

    /// Preset: background eval — latency-tolerant, lowest priority,
    /// small goodput weight.
    pub fn background() -> TrafficClass {
        TrafficClass::new("background", 0, 8.0, 0.5, 0.25)
    }

    /// Does a completed request meet this class's SLO? The single
    /// compliance predicate behind goodput / attainment / J-per-good-
    /// token (previously triplicated as scalar filters in `metrics.rs`).
    pub fn met_by(&self, m: &RequestMetrics) -> bool {
        m.ttft <= self.ttft_slo && m.tpot <= self.tpot_slo
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.name.is_empty() {
            anyhow::bail!("traffic class name must be non-empty");
        }
        if !(self.ttft_slo > 0.0 && self.ttft_slo.is_finite())
            || !(self.tpot_slo > 0.0 && self.tpot_slo.is_finite())
        {
            anyhow::bail!("class '{}': SLOs must be positive and finite", self.name);
        }
        if !(self.weight > 0.0 && self.weight.is_finite()) {
            anyhow::bail!("class '{}': weight must be positive and finite", self.name);
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("priority", Json::Num(self.priority as f64)),
            ("ttft_slo", Json::Num(self.ttft_slo)),
            ("tpot_slo", Json::Num(self.tpot_slo)),
            ("weight", Json::Num(self.weight)),
        ])
    }

    /// Parse one class from a config-JSON object. `name` is required;
    /// every other field defaults from [`TrafficClass::default_class`].
    pub fn from_json(j: &Json) -> anyhow::Result<TrafficClass> {
        let d = TrafficClass::default_class();
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("traffic class needs a string 'name'"))?
            .to_string();
        let num = |key: &str, dflt: f64| -> anyhow::Result<f64> {
            match j.get(key) {
                None => Ok(dflt),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("class '{name}': bad field '{key}'")),
            }
        };
        let priority = num("priority", d.priority as f64)?;
        if priority < 0.0 || priority.fract() != 0.0 || priority > u8::MAX as f64 {
            anyhow::bail!("class '{name}': priority must be an integer in 0..=255");
        }
        // Pull every field through the closure before `name` moves into
        // the struct (the closure borrows `name` for its error messages).
        let ttft_slo = num("ttft_slo", d.ttft_slo)?;
        let tpot_slo = num("tpot_slo", d.tpot_slo)?;
        let weight = num("weight", d.weight)?;
        let c = TrafficClass { name, priority: priority as u8, ttft_slo, tpot_slo, weight };
        c.validate()?;
        Ok(c)
    }
}

/// The declared traffic classes of a deployment, indexed by [`ClassId`].
/// Never empty: the single-element default reproduces the legacy
/// scalar-SLO behavior.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassSet {
    classes: Vec<TrafficClass>,
}

impl Default for ClassSet {
    fn default() -> Self {
        ClassSet { classes: vec![TrafficClass::default_class()] }
    }
}

impl ClassSet {
    pub fn new(classes: Vec<TrafficClass>) -> anyhow::Result<ClassSet> {
        let set = ClassSet { classes };
        set.validate()?;
        Ok(set)
    }

    /// One-class set (the legacy shape).
    pub fn single(class: TrafficClass) -> ClassSet {
        ClassSet { classes: vec![class] }
    }

    /// Back-compat shim for call sites that still think in a bare
    /// `(ttft_slo, tpot_slo)` pair: a single anonymous priority-0 class.
    pub fn scalar(ttft_slo: f64, tpot_slo: f64) -> ClassSet {
        ClassSet::single(TrafficClass::scalar(ttft_slo, tpot_slo))
    }

    /// The interactive / batch / background preset fleet mix.
    pub fn three_tier() -> ClassSet {
        ClassSet {
            classes: vec![
                TrafficClass::interactive(),
                TrafficClass::batch(),
                TrafficClass::background(),
            ],
        }
    }

    /// The class-blind baseline: same names, SLOs and weights, every
    /// priority flattened to 0 — FIFO admission, youngest-first
    /// preemption, no routing penalty. The control arm of the qos-sweep
    /// experiment's "priorities help interactive traffic" claim.
    pub fn flatten_priorities(&self) -> ClassSet {
        ClassSet {
            classes: self
                .classes
                .iter()
                .map(|c| TrafficClass { priority: 0, ..c.clone() })
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.classes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.classes.is_empty()
    }

    pub fn get(&self, id: ClassId) -> Option<&TrafficClass> {
        self.classes.get(id)
    }

    /// The class of `id`; panics on an undeclared id (the scheduler
    /// rejects such requests at submission).
    pub fn class(&self, id: ClassId) -> &TrafficClass {
        self.classes.get(id).unwrap_or_else(|| {
            panic!("class id {id} not declared (only {} classes)", self.classes.len())
        })
    }

    pub fn iter(&self) -> impl Iterator<Item = &TrafficClass> {
        self.classes.iter()
    }

    /// Scheduling priority of `id`; 0 (the neutral legacy priority) for
    /// ids outside the set, so components that may see untagged traffic
    /// (the router) degrade safely instead of panicking.
    pub fn priority_of(&self, id: ClassId) -> u8 {
        self.classes.get(id).map_or(0, |c| c.priority)
    }

    /// The class id metrics of `id` are *judged and bucketed* under: the
    /// declared id, or 0 for ids outside the set. Measurement sets are
    /// allowed to be smaller than the serving set — judging a
    /// mixed-class run with a single-class set reproduces the legacy
    /// global-scalar-SLO measurement instead of panicking (the
    /// autoscaler's `AutoscaleConfig::classes` is such an independent
    /// measurement set).
    pub fn judging_id(&self, id: ClassId) -> ClassId {
        if id < self.classes.len() {
            id
        } else {
            0
        }
    }

    /// The class metrics of `id` are judged under (see
    /// [`judging_id`](Self::judging_id)).
    pub fn judging_class(&self, id: ClassId) -> &TrafficClass {
        &self.classes[self.judging_id(id)]
    }

    /// Does a completed request meet its class's SLO (its own class, or
    /// class 0 when this — measurement — set doesn't declare it)?
    pub fn met_by(&self, m: &RequestMetrics) -> bool {
        self.judging_class(m.class_id).met_by(m)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.classes.is_empty() {
            anyhow::bail!("classes must not be empty (use the default class)");
        }
        for c in &self.classes {
            c.validate()?;
        }
        for (i, c) in self.classes.iter().enumerate() {
            if self.classes[..i].iter().any(|o| o.name == c.name) {
                anyhow::bail!("duplicate traffic class name '{}'", c.name);
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.classes.iter().map(|c| c.to_json()).collect())
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ClassSet> {
        let arr = j
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'classes' must be an array of class objects"))?;
        let classes = arr
            .iter()
            .map(TrafficClass::from_json)
            .collect::<anyhow::Result<Vec<TrafficClass>>>()?;
        ClassSet::new(classes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(class_id: ClassId, ttft: f64, tpot: f64) -> RequestMetrics {
        RequestMetrics {
            id: 1,
            ttft,
            tpot,
            e2e: ttft + tpot,
            finish: 1.0,
            output_tokens: 10,
            class_id,
        }
    }

    #[test]
    fn default_class_is_the_legacy_scalar_slo() {
        let d = TrafficClass::default_class();
        assert_eq!((d.priority, d.ttft_slo, d.tpot_slo, d.weight), (0, 1.0, 0.1, 1.0));
        assert_eq!(ClassSet::default().len(), 1);
        assert_eq!(ClassSet::default().class(0).name, "default");
    }

    #[test]
    fn met_by_dispatches_on_the_request_class() {
        let set = ClassSet::three_tier();
        // 0.4s TTFT / 0.04s TPOT meets interactive (0.5/0.05)...
        assert!(set.met_by(&m(0, 0.4, 0.04)));
        // ...but 1.0s TTFT only meets batch and background.
        assert!(!set.met_by(&m(0, 1.0, 0.04)));
        assert!(set.met_by(&m(1, 1.0, 0.04)));
        assert!(set.met_by(&m(2, 5.0, 0.4)));
        assert!(!set.met_by(&m(2, 9.0, 0.4)));
    }

    #[test]
    fn flatten_keeps_slos_and_weights_but_zeroes_priority() {
        let flat = ClassSet::three_tier().flatten_priorities();
        assert!(flat.iter().all(|c| c.priority == 0));
        assert_eq!(flat.class(0).ttft_slo, TrafficClass::interactive().ttft_slo);
        assert_eq!(flat.class(1).weight, TrafficClass::batch().weight);
    }

    #[test]
    fn priority_of_is_total() {
        let set = ClassSet::three_tier();
        assert_eq!(set.priority_of(0), 2);
        assert_eq!(set.priority_of(99), 0, "undeclared ids fall back to neutral priority");
    }

    #[test]
    fn judging_is_total_over_foreign_class_ids() {
        // A 1-class measurement set judges a mixed-class run's metrics
        // against its single (legacy global) SLO instead of panicking —
        // the autoscaler's independent ClassSet depends on this.
        let scalar = ClassSet::scalar(1.0, 0.1);
        assert_eq!(scalar.judging_id(2), 0);
        assert!(scalar.met_by(&m(2, 0.5, 0.05)));
        assert!(!scalar.met_by(&m(7, 2.0, 0.05)));
        // In-range ids judge under their own class.
        let three = ClassSet::three_tier();
        assert_eq!(three.judging_id(2), 2);
        assert_eq!(three.judging_class(1).name, "batch");
    }

    #[test]
    fn json_roundtrip() {
        let set = ClassSet::three_tier();
        let j = Json::parse(&set.to_json().dump()).unwrap();
        assert_eq!(ClassSet::from_json(&j).unwrap(), set);
    }

    #[test]
    fn from_json_defaults_and_rejects() {
        let j = Json::parse(r#"[{"name": "only"}]"#).unwrap();
        let set = ClassSet::from_json(&j).unwrap();
        let d = TrafficClass::default_class();
        assert_eq!(set.class(0).ttft_slo, d.ttft_slo);
        assert_eq!(set.class(0).priority, d.priority);
        for bad in [
            r#"[{"priority": 1}]"#,                       // missing name
            r#"[{"name": "a"}, {"name": "a"}]"#,          // duplicate
            r#"[{"name": "a", "ttft_slo": -1.0}]"#,       // bad SLO
            r#"[{"name": "a", "priority": 1.5}]"#,        // fractional priority
            r#"[{"name": "a", "weight": 0.0}]"#,          // bad weight
            r#"[]"#,                                       // empty
            r#"{"name": "a"}"#,                            // not an array
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ClassSet::from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn scalar_shim_carries_the_pair() {
        let set = ClassSet::scalar(0.25, 0.02);
        assert_eq!(set.len(), 1);
        assert!(set.met_by(&m(0, 0.2, 0.01)));
        assert!(!set.met_by(&m(0, 0.3, 0.01)));
        assert_eq!(set.class(0).priority, 0, "shims never change scheduling");
    }
}
