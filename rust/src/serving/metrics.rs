//! Serving metrics: TTFT (time-to-first-token), TPOT (time-per-output-
//! token), end-to-end latency and throughput — the SLO metrics of
//! Fig 17(d,e). `MetricsCollector` instances merge, so
//! `serving::cluster::ClusterSim` folds per-replica collectors into
//! fleet-level percentiles and goodput-under-SLO.

use crate::serving::request::{RequestId, Sequence};
use crate::util::json::Json;
use crate::util::stats::{mean, percentile};

/// Metrics for one completed request.
#[derive(Debug, Clone, Copy)]
pub struct RequestMetrics {
    pub id: RequestId,
    pub ttft: f64,
    pub tpot: f64,
    pub e2e: f64,
    /// Engine-clock completion time — lets controllers (the autoscaler)
    /// evaluate SLO attainment over a recent window instead of the whole
    /// run's history.
    pub finish: f64,
    pub output_tokens: usize,
}

impl RequestMetrics {
    /// Extract from a finished sequence.
    pub fn from_sequence(s: &Sequence) -> RequestMetrics {
        let first = s.first_token_time.expect("finished sequence has first token");
        let finish = s.finish_time.expect("finished sequence has finish time");
        let ttft = first - s.req.arrival;
        let decode_span = finish - first;
        let tpot = if s.generated > 1 { decode_span / (s.generated - 1) as f64 } else { 0.0 };
        RequestMetrics {
            id: s.req.id,
            ttft,
            tpot,
            e2e: finish - s.req.arrival,
            finish,
            output_tokens: s.generated,
        }
    }

    /// Does this request meet a (TTFT, TPOT) service-level objective?
    pub fn meets_slo(&self, ttft_slo: f64, tpot_slo: f64) -> bool {
        self.ttft <= ttft_slo && self.tpot <= tpot_slo
    }
}

/// Aggregate over a run.
#[derive(Debug, Clone, Default)]
pub struct MetricsCollector {
    per_request: Vec<RequestMetrics>,
    /// Engine-clock span of the run (set by the engine at the end).
    pub makespan: f64,
    /// Joules drawn while executing steps (device power model x busy
    /// time, accumulated by the engine; 0 for backends without an energy
    /// model). The deployment-cost numerator of J-per-good-token.
    pub energy_j: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct MetricsSummary {
    pub requests: usize,
    pub mean_ttft: f64,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    pub mean_tpot: f64,
    pub p50_tpot: f64,
    pub p99_tpot: f64,
    pub mean_e2e: f64,
    /// Output tokens per second over the makespan.
    pub throughput_tps: f64,
    /// Requests per second over the makespan.
    pub throughput_rps: f64,
    /// Busy-time energy over the run (joules).
    pub energy_j: f64,
    /// Joules per generated output token (0 when no energy was modeled).
    pub joule_per_tok: f64,
}

impl MetricsSummary {
    /// Machine-readable summary (times in seconds, throughputs per
    /// second) — the `repro serve --json` payload.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("mean_ttft_s", Json::Num(self.mean_ttft)),
            ("p50_ttft_s", Json::Num(self.p50_ttft)),
            ("p99_ttft_s", Json::Num(self.p99_ttft)),
            ("mean_tpot_s", Json::Num(self.mean_tpot)),
            ("p50_tpot_s", Json::Num(self.p50_tpot)),
            ("p99_tpot_s", Json::Num(self.p99_tpot)),
            ("mean_e2e_s", Json::Num(self.mean_e2e)),
            ("throughput_tok_per_s", Json::Num(self.throughput_tps)),
            ("throughput_req_per_s", Json::Num(self.throughput_rps)),
            ("energy_j", Json::Num(self.energy_j)),
            ("joule_per_tok", Json::Num(self.joule_per_tok)),
        ])
    }
}

impl MetricsCollector {
    pub fn record(&mut self, m: RequestMetrics) {
        self.per_request.push(m);
    }

    pub fn len(&self) -> usize {
        self.per_request.len()
    }

    pub fn is_empty(&self) -> bool {
        self.per_request.is_empty()
    }

    /// Per-request metrics, in completion order.
    pub fn per_request(&self) -> &[RequestMetrics] {
        &self.per_request
    }

    /// Total output tokens over all completed requests.
    pub fn output_tokens(&self) -> usize {
        self.per_request.iter().map(|m| m.output_tokens).sum()
    }

    /// Fold another collector (e.g. one replica's) into this one. The
    /// merged makespan is the max — replicas run concurrently, so the
    /// fleet span is the slowest replica's span.
    pub fn merge(&mut self, other: &MetricsCollector) {
        self.per_request.extend_from_slice(&other.per_request);
        self.makespan = self.makespan.max(other.makespan);
        self.energy_j += other.energy_j;
    }

    /// Goodput under a (TTFT, TPOT) SLO: completed-and-compliant requests
    /// per second over the makespan — the deployment-sizing metric of the
    /// cluster experiment.
    pub fn goodput_under_slo(&self, ttft_slo: f64, tpot_slo: f64) -> f64 {
        let ok = self.per_request.iter().filter(|m| m.meets_slo(ttft_slo, tpot_slo)).count();
        ok as f64 / self.makespan.max(1e-12)
    }

    /// Max per-request metric delta against another run on the same
    /// trace: the largest |TTFT/TPOT/E2E| difference over id-matched
    /// requests, the |makespan| difference, and +1 for every request
    /// count mismatch or unmatched id. Exactly 0.0 iff the two runs are
    /// bitwise-identical — the comparator behind every bitwise-parity
    /// claim (1-replica cluster ≡ engine, mixed ≡ homogeneous fleet,
    /// unbounded prefix cache ≡ legacy warm set).
    pub fn max_request_delta(&self, other: &MetricsCollector) -> f64 {
        let mut delta = self.per_request.len().abs_diff(other.per_request.len()) as f64;
        delta = delta.max((self.makespan - other.makespan).abs());
        for m in &self.per_request {
            match other.per_request.iter().find(|h| h.id == m.id) {
                Some(h) => {
                    delta = delta
                        .max((m.ttft - h.ttft).abs())
                        .max((m.tpot - h.tpot).abs())
                        .max((m.e2e - h.e2e).abs());
                }
                None => delta += 1.0,
            }
        }
        delta
    }

    /// Joules per *good* output token — energy divided by the output
    /// tokens of SLO-compliant requests: the autoscaler's cost-per-
    /// goodput metric. `None` when no request met the SLO (cost would be
    /// infinite) or no energy was modeled.
    pub fn energy_per_good_token(&self, ttft_slo: f64, tpot_slo: f64) -> Option<f64> {
        let good_tokens: usize = self
            .per_request
            .iter()
            .filter(|m| m.meets_slo(ttft_slo, tpot_slo))
            .map(|m| m.output_tokens)
            .sum();
        (good_tokens > 0 && self.energy_j > 0.0)
            .then(|| self.energy_j / good_tokens as f64)
    }

    /// Fraction of completed requests meeting the SLO.
    pub fn slo_attainment(&self, ttft_slo: f64, tpot_slo: f64) -> f64 {
        if self.per_request.is_empty() {
            return 0.0;
        }
        let ok = self.per_request.iter().filter(|m| m.meets_slo(ttft_slo, tpot_slo)).count();
        ok as f64 / self.per_request.len() as f64
    }

    pub fn summary(&self) -> MetricsSummary {
        let ttfts: Vec<f64> = self.per_request.iter().map(|m| m.ttft).collect();
        let tpots: Vec<f64> =
            self.per_request.iter().filter(|m| m.output_tokens > 1).map(|m| m.tpot).collect();
        let e2es: Vec<f64> = self.per_request.iter().map(|m| m.e2e).collect();
        let tokens: usize = self.per_request.iter().map(|m| m.output_tokens).sum();
        let span = self.makespan.max(1e-12);
        MetricsSummary {
            requests: self.per_request.len(),
            mean_ttft: mean(&ttfts),
            p50_ttft: percentile(&ttfts, 50.0),
            p99_ttft: percentile(&ttfts, 99.0),
            mean_tpot: mean(&tpots),
            p50_tpot: percentile(&tpots, 50.0),
            p99_tpot: percentile(&tpots, 99.0),
            mean_e2e: mean(&e2es),
            throughput_tps: tokens as f64 / span,
            throughput_rps: self.per_request.len() as f64 / span,
            energy_j: self.energy_j,
            joule_per_tok: if tokens == 0 { 0.0 } else { self.energy_j / tokens as f64 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::request::{Phase, Request};

    fn finished_seq(arrival: f64, first: f64, finish: f64, gen: usize) -> Sequence {
        let mut s = Sequence::new(Request::new(1, 10, gen, arrival));
        s.phase = Phase::Finished;
        s.generated = gen;
        s.first_token_time = Some(first);
        s.finish_time = Some(finish);
        s
    }

    fn m(id: RequestId, ttft: f64) -> RequestMetrics {
        RequestMetrics { id, ttft, tpot: 0.01, e2e: 1.0, finish: id as f64, output_tokens: 100 }
    }

    #[test]
    fn request_metrics_math() {
        let rm = RequestMetrics::from_sequence(&finished_seq(1.0, 1.5, 2.5, 11));
        assert_eq!(rm.id, 1);
        assert!((rm.ttft - 0.5).abs() < 1e-12);
        assert!((rm.tpot - 0.1).abs() < 1e-12);
        assert!((rm.e2e - 1.5).abs() < 1e-12);
    }

    #[test]
    fn single_token_has_zero_tpot() {
        let rm = RequestMetrics::from_sequence(&finished_seq(0.0, 0.2, 0.2, 1));
        assert_eq!(rm.tpot, 0.0);
    }

    #[test]
    fn summary_aggregates() {
        let mut c = MetricsCollector::default();
        for i in 0..10 {
            c.record(m(i, 0.1 * (i + 1) as f64));
        }
        c.makespan = 10.0;
        let s = c.summary();
        assert_eq!(s.requests, 10);
        assert!((s.mean_ttft - 0.55).abs() < 1e-9);
        assert!((s.throughput_tps - 100.0).abs() < 1e-9);
        assert!((s.throughput_rps - 1.0).abs() < 1e-9);
        assert!(s.p99_ttft >= s.mean_ttft);
        assert!(s.p50_ttft <= s.p99_ttft);
        assert_eq!(c.output_tokens(), 1000);
    }

    #[test]
    fn merge_concatenates_and_takes_max_makespan() {
        let mut a = MetricsCollector::default();
        a.record(m(0, 0.1));
        a.makespan = 4.0;
        let mut b = MetricsCollector::default();
        b.record(m(1, 0.3));
        b.record(m(2, 0.2));
        b.makespan = 9.0;
        a.merge(&b);
        assert_eq!(a.len(), 3);
        assert_eq!(a.makespan, 9.0);
        // Fleet tokens = sum of replica tokens.
        assert_eq!(a.output_tokens(), 300);
    }

    #[test]
    fn summary_json_has_every_field() {
        let mut c = MetricsCollector::default();
        c.record(m(0, 0.25));
        c.makespan = 2.0;
        let j = crate::util::json::Json::parse(&c.summary().to_json().dump()).unwrap();
        assert_eq!(j.get("requests").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("mean_ttft_s").unwrap().as_f64(), Some(0.25));
        assert_eq!(j.get("throughput_tok_per_s").unwrap().as_f64(), Some(50.0));
        assert_eq!(j.get("throughput_req_per_s").unwrap().as_f64(), Some(0.5));
    }

    #[test]
    fn energy_merges_and_summarizes() {
        let mut a = MetricsCollector::default();
        a.record(m(0, 0.1)); // 100 output tokens
        a.makespan = 2.0;
        a.energy_j = 500.0;
        let mut b = MetricsCollector::default();
        b.record(m(1, 0.5));
        b.energy_j = 300.0;
        a.merge(&b);
        assert_eq!(a.energy_j, 800.0);
        let s = a.summary();
        assert_eq!(s.energy_j, 800.0);
        assert!((s.joule_per_tok - 4.0).abs() < 1e-12);
        let j = s.to_json();
        assert_eq!(j.get("energy_j").unwrap().as_f64(), Some(800.0));
        assert_eq!(j.get("joule_per_tok").unwrap().as_f64(), Some(s.joule_per_tok));
        // J per *good* token under a TTFT SLO only request 0 meets.
        assert_eq!(a.energy_per_good_token(0.2, 1.0), Some(8.0));
        // Nobody compliant -> no finite cost.
        assert_eq!(a.energy_per_good_token(0.01, 1.0), None);
        // No energy modeled -> None.
        assert_eq!(MetricsCollector::default().energy_per_good_token(1.0, 1.0), None);
    }

    #[test]
    fn goodput_and_attainment() {
        let mut c = MetricsCollector::default();
        c.record(m(0, 0.1)); // compliant (ttft <= 0.2)
        c.record(m(1, 0.5)); // violates TTFT SLO
        c.makespan = 2.0;
        assert!((c.goodput_under_slo(0.2, 0.05) - 0.5).abs() < 1e-12);
        assert!((c.slo_attainment(0.2, 0.05) - 0.5).abs() < 1e-12);
        // Tightening the TPOT SLO below 0.01 kills both.
        assert_eq!(c.goodput_under_slo(0.2, 0.001), 0.0);
    }
}
