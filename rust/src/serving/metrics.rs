//! Serving metrics: TTFT (time-to-first-token), TPOT (time-per-output-
//! token), end-to-end latency and throughput — the SLO metrics of
//! Fig 17(d,e).

use crate::serving::request::Sequence;
use crate::util::stats::{mean, percentile};

/// Metrics for one completed request.
#[derive(Debug, Clone, Copy)]
pub struct RequestMetrics {
    pub ttft: f64,
    pub tpot: f64,
    pub e2e: f64,
    pub output_tokens: usize,
}

impl RequestMetrics {
    /// Extract from a finished sequence.
    pub fn from_sequence(s: &Sequence) -> RequestMetrics {
        let first = s.first_token_time.expect("finished sequence has first token");
        let finish = s.finish_time.expect("finished sequence has finish time");
        let ttft = first - s.req.arrival;
        let decode_span = finish - first;
        let tpot = if s.generated > 1 { decode_span / (s.generated - 1) as f64 } else { 0.0 };
        RequestMetrics { ttft, tpot, e2e: finish - s.req.arrival, output_tokens: s.generated }
    }
}

/// Aggregate over a run.
#[derive(Debug, Clone, Default)]
pub struct MetricsCollector {
    per_request: Vec<RequestMetrics>,
    /// Engine-clock span of the run (set by the engine at the end).
    pub makespan: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct MetricsSummary {
    pub requests: usize,
    pub mean_ttft: f64,
    pub p99_ttft: f64,
    pub mean_tpot: f64,
    pub p99_tpot: f64,
    pub mean_e2e: f64,
    /// Output tokens per second over the makespan.
    pub throughput_tps: f64,
    /// Requests per second over the makespan.
    pub throughput_rps: f64,
}

impl MetricsCollector {
    pub fn record(&mut self, m: RequestMetrics) {
        self.per_request.push(m);
    }

    pub fn len(&self) -> usize {
        self.per_request.len()
    }

    pub fn is_empty(&self) -> bool {
        self.per_request.is_empty()
    }

    pub fn summary(&self) -> MetricsSummary {
        let ttfts: Vec<f64> = self.per_request.iter().map(|m| m.ttft).collect();
        let tpots: Vec<f64> =
            self.per_request.iter().filter(|m| m.output_tokens > 1).map(|m| m.tpot).collect();
        let e2es: Vec<f64> = self.per_request.iter().map(|m| m.e2e).collect();
        let tokens: usize = self.per_request.iter().map(|m| m.output_tokens).sum();
        let span = self.makespan.max(1e-12);
        MetricsSummary {
            requests: self.per_request.len(),
            mean_ttft: mean(&ttfts),
            p99_ttft: percentile(&ttfts, 99.0),
            mean_tpot: mean(&tpots),
            p99_tpot: percentile(&tpots, 99.0),
            mean_e2e: mean(&e2es),
            throughput_tps: tokens as f64 / span,
            throughput_rps: self.per_request.len() as f64 / span,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::request::{Phase, Request};

    fn finished_seq(arrival: f64, first: f64, finish: f64, gen: usize) -> Sequence {
        let mut s = Sequence::new(Request::new(1, 10, gen, arrival));
        s.phase = Phase::Finished;
        s.generated = gen;
        s.first_token_time = Some(first);
        s.finish_time = Some(finish);
        s
    }

    #[test]
    fn request_metrics_math() {
        let m = RequestMetrics::from_sequence(&finished_seq(1.0, 1.5, 2.5, 11));
        assert!((m.ttft - 0.5).abs() < 1e-12);
        assert!((m.tpot - 0.1).abs() < 1e-12);
        assert!((m.e2e - 1.5).abs() < 1e-12);
    }

    #[test]
    fn single_token_has_zero_tpot() {
        let m = RequestMetrics::from_sequence(&finished_seq(0.0, 0.2, 0.2, 1));
        assert_eq!(m.tpot, 0.0);
    }

    #[test]
    fn summary_aggregates() {
        let mut c = MetricsCollector::default();
        for i in 0..10 {
            c.record(RequestMetrics {
                ttft: 0.1 * (i + 1) as f64,
                tpot: 0.01,
                e2e: 1.0,
                output_tokens: 100,
            });
        }
        c.makespan = 10.0;
        let s = c.summary();
        assert_eq!(s.requests, 10);
        assert!((s.mean_ttft - 0.55).abs() < 1e-9);
        assert!((s.throughput_tps - 100.0).abs() < 1e-9);
        assert!((s.throughput_rps - 1.0).abs() < 1e-9);
        assert!(s.p99_ttft >= s.mean_ttft);
    }
}
