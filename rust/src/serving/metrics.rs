//! Serving metrics: TTFT (time-to-first-token), TPOT (time-per-output-
//! token), end-to-end latency and throughput — the SLO metrics of
//! Fig 17(d,e). `MetricsCollector` instances merge, so
//! `serving::cluster::ClusterSim` folds per-replica collectors into
//! fleet-level percentiles and goodput-under-SLO.
//!
//! SLO compliance is per traffic class (`serving::qos`): every request
//! carries a `ClassId`, and goodput / attainment / J-per-good-token
//! filter each request against *its own class's* SLO through one shared
//! [`MetricsCollector::compliant`] helper (previously three separately
//! maintained scalar filters). Per-class breakdowns ([`ClassSummary`])
//! flow into [`MetricsSummary`] and `repro serve --json`.

use crate::serving::qos::{ClassId, ClassSet};
use crate::serving::request::{RequestId, Sequence};
use crate::util::json::Json;
use crate::util::stats::{mean, percentile};

/// Metrics for one completed request.
#[derive(Debug, Clone, Copy)]
pub struct RequestMetrics {
    pub id: RequestId,
    pub ttft: f64,
    pub tpot: f64,
    pub e2e: f64,
    /// Engine-clock completion time — lets controllers (the autoscaler)
    /// evaluate SLO attainment over a recent window instead of the whole
    /// run's history.
    pub finish: f64,
    pub output_tokens: usize,
    /// Traffic class the request was served under — fixes which SLO its
    /// compliance is judged against.
    pub class_id: ClassId,
}

impl RequestMetrics {
    /// Extract from a finished sequence.
    pub fn from_sequence(s: &Sequence) -> RequestMetrics {
        let first = s.first_token_time.expect("finished sequence has first token");
        let finish = s.finish_time.expect("finished sequence has finish time");
        let ttft = first - s.req.arrival;
        let decode_span = finish - first;
        let tpot = if s.generated > 1 { decode_span / (s.generated - 1) as f64 } else { 0.0 };
        RequestMetrics {
            id: s.req.id,
            ttft,
            tpot,
            e2e: finish - s.req.arrival,
            finish,
            output_tokens: s.generated,
            class_id: s.req.class_id,
        }
    }
}

/// Aggregate over a run.
#[derive(Debug, Clone, Default)]
pub struct MetricsCollector {
    per_request: Vec<RequestMetrics>,
    /// Engine-clock span of the run (set by the engine at the end).
    pub makespan: f64,
    /// Joules drawn while executing steps (device power model x busy
    /// time, accumulated by the engine; 0 for backends without an energy
    /// model). The deployment-cost numerator of J-per-good-token.
    pub energy_j: f64,
}

/// Per-traffic-class slice of a run's metrics — the QoS breakdown of
/// `repro serve --json` and the qos-sweep experiment.
#[derive(Debug, Clone)]
pub struct ClassSummary {
    pub class_id: ClassId,
    pub name: String,
    pub requests: usize,
    /// Fraction of this class's completions meeting the class SLO.
    pub attainment: f64,
    /// SLO-compliant completions of this class per second of makespan.
    pub goodput_rps: f64,
    pub mean_ttft: f64,
    pub p99_ttft: f64,
    pub mean_tpot: f64,
    /// Joules per *good* token of this class, with run energy attributed
    /// to classes by output-token share (the simulator meters energy per
    /// step, not per sequence). `None` when nothing complied or no
    /// energy was modeled.
    pub joule_per_good_tok: Option<f64>,
}

impl ClassSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("class", Json::Num(self.class_id as f64)),
            ("name", Json::Str(self.name.clone())),
            ("requests", Json::Num(self.requests as f64)),
            ("attainment", Json::Num(self.attainment)),
            ("goodput_req_per_s", Json::Num(self.goodput_rps)),
            ("mean_ttft_s", Json::Num(self.mean_ttft)),
            ("p99_ttft_s", Json::Num(self.p99_ttft)),
            ("mean_tpot_s", Json::Num(self.mean_tpot)),
            (
                "joule_per_good_tok",
                match self.joule_per_good_tok {
                    Some(j) => Json::Num(j),
                    None => Json::Null,
                },
            ),
        ])
    }
}

#[derive(Debug, Clone)]
pub struct MetricsSummary {
    pub requests: usize,
    pub mean_ttft: f64,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    pub mean_tpot: f64,
    pub p50_tpot: f64,
    pub p99_tpot: f64,
    pub mean_e2e: f64,
    /// Output tokens per second over the makespan.
    pub throughput_tps: f64,
    /// Requests per second over the makespan.
    pub throughput_rps: f64,
    /// Busy-time energy over the run (joules).
    pub energy_j: f64,
    /// Joules per generated output token (0 when no energy was modeled).
    pub joule_per_tok: f64,
    /// Per-traffic-class breakdown (empty when the summary was built
    /// without a `ClassSet` — `summary()` vs `summary_for()`).
    pub classes: Vec<ClassSummary>,
}

impl MetricsSummary {
    /// Machine-readable summary (times in seconds, throughputs per
    /// second) — the `repro serve --json` payload. Includes one entry
    /// per traffic class when the summary carries a breakdown.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("mean_ttft_s", Json::Num(self.mean_ttft)),
            ("p50_ttft_s", Json::Num(self.p50_ttft)),
            ("p99_ttft_s", Json::Num(self.p99_ttft)),
            ("mean_tpot_s", Json::Num(self.mean_tpot)),
            ("p50_tpot_s", Json::Num(self.p50_tpot)),
            ("p99_tpot_s", Json::Num(self.p99_tpot)),
            ("mean_e2e_s", Json::Num(self.mean_e2e)),
            ("throughput_tok_per_s", Json::Num(self.throughput_tps)),
            ("throughput_req_per_s", Json::Num(self.throughput_rps)),
            ("energy_j", Json::Num(self.energy_j)),
            ("joule_per_tok", Json::Num(self.joule_per_tok)),
            ("classes", Json::Arr(self.classes.iter().map(|c| c.to_json()).collect())),
        ])
    }
}

impl MetricsCollector {
    pub fn record(&mut self, m: RequestMetrics) {
        self.per_request.push(m);
    }

    pub fn len(&self) -> usize {
        self.per_request.len()
    }

    pub fn is_empty(&self) -> bool {
        self.per_request.is_empty()
    }

    /// Per-request metrics, in completion order.
    pub fn per_request(&self) -> &[RequestMetrics] {
        &self.per_request
    }

    /// Total output tokens over all completed requests.
    pub fn output_tokens(&self) -> usize {
        self.per_request.iter().map(|m| m.output_tokens).sum()
    }

    /// Fold another collector (e.g. one replica's) into this one. The
    /// merged makespan is the max — replicas run concurrently, so the
    /// fleet span is the slowest replica's span.
    pub fn merge(&mut self, other: &MetricsCollector) {
        self.per_request.extend_from_slice(&other.per_request);
        self.makespan = self.makespan.max(other.makespan);
        self.energy_j += other.energy_j;
    }

    /// Requests compliant with *their own class's* SLO — the single
    /// filter behind goodput, attainment and J-per-good-token (formerly
    /// three hand-rolled scalar filters that had to be kept in sync).
    fn compliant<'a>(
        &'a self,
        classes: &'a ClassSet,
    ) -> impl Iterator<Item = &'a RequestMetrics> + 'a {
        self.per_request.iter().filter(move |m| classes.met_by(m))
    }

    /// Goodput under the deployment's traffic classes: completed-and-
    /// compliant requests (each against its own class SLO) per second of
    /// makespan — the deployment-sizing metric of the cluster experiments.
    pub fn goodput(&self, classes: &ClassSet) -> f64 {
        self.compliant(classes).count() as f64 / self.makespan.max(1e-12)
    }

    /// Fraction of completed requests meeting their class SLO.
    pub fn attainment(&self, classes: &ClassSet) -> f64 {
        if self.per_request.is_empty() {
            return 0.0;
        }
        self.compliant(classes).count() as f64 / self.per_request.len() as f64
    }

    /// Goodput-weighted attainment: per-class attainment folded by class
    /// weight over classes that completed at least one request — the
    /// autoscaler's control signal. With a single weight-1 class this is
    /// exactly [`attainment`](Self::attainment). 0.0 on an empty run.
    pub fn weighted_attainment(&self, classes: &ClassSet) -> f64 {
        let per = self.class_breakdown(classes);
        let (mut num, mut den) = (0.0, 0.0);
        for c in &per {
            if c.requests > 0 {
                let w = classes.class(c.class_id).weight;
                num += w * c.attainment;
                den += w;
            }
        }
        if den > 0.0 {
            num / den
        } else {
            0.0
        }
    }

    /// Max per-request metric delta against another run on the same
    /// trace: the largest |TTFT/TPOT/E2E| difference over id-matched
    /// requests, the |makespan| difference, and +1 for every request
    /// count mismatch or unmatched id. Exactly 0.0 iff the two runs are
    /// bitwise-identical — the comparator behind every bitwise-parity
    /// claim (1-replica cluster ≡ engine, mixed ≡ homogeneous fleet,
    /// unbounded prefix cache ≡ legacy warm set, single default class ≡
    /// scalar-SLO path).
    pub fn max_request_delta(&self, other: &MetricsCollector) -> f64 {
        let mut delta = self.per_request.len().abs_diff(other.per_request.len()) as f64;
        delta = delta.max((self.makespan - other.makespan).abs());
        for m in &self.per_request {
            match other.per_request.iter().find(|h| h.id == m.id) {
                Some(h) => {
                    delta = delta
                        .max((m.ttft - h.ttft).abs())
                        .max((m.tpot - h.tpot).abs())
                        .max((m.e2e - h.e2e).abs());
                }
                None => delta += 1.0,
            }
        }
        delta
    }

    /// Joules per *good* output token — energy divided by the output
    /// tokens of requests compliant with their class SLO: the
    /// autoscaler's cost-per-goodput metric. `None` when no request met
    /// its SLO (cost would be infinite) or no energy was modeled.
    pub fn energy_per_good_token(&self, classes: &ClassSet) -> Option<f64> {
        let good_tokens: usize = self.compliant(classes).map(|m| m.output_tokens).sum();
        (good_tokens > 0 && self.energy_j > 0.0).then(|| self.energy_j / good_tokens as f64)
    }

    /// Per-class slices of the run: one [`ClassSummary`] per declared
    /// class (classes with no completions report zeros). Run energy is
    /// attributed to classes by output-token share.
    pub fn class_breakdown(&self, classes: &ClassSet) -> Vec<ClassSummary> {
        let total_tokens = self.output_tokens();
        let span = self.makespan.max(1e-12);
        (0..classes.len())
            .map(|cid| {
                let class = classes.class(cid);
                // Bucket by the measurement set's judging id: ids this
                // set doesn't declare fold into class 0 (the legacy
                // global-SLO slice) instead of vanishing or panicking.
                let of_class: Vec<&RequestMetrics> = self
                    .per_request
                    .iter()
                    .filter(|m| classes.judging_id(m.class_id) == cid)
                    .collect();
                let ttfts: Vec<f64> = of_class.iter().map(|m| m.ttft).collect();
                let tpots: Vec<f64> = of_class
                    .iter()
                    .filter(|m| m.output_tokens > 1)
                    .map(|m| m.tpot)
                    .collect();
                let ok = of_class.iter().filter(|m| class.met_by(m)).count();
                let good_tokens: usize = of_class
                    .iter()
                    .filter(|m| class.met_by(m))
                    .map(|m| m.output_tokens)
                    .sum();
                let class_tokens: usize = of_class.iter().map(|m| m.output_tokens).sum();
                let class_energy = if total_tokens == 0 {
                    0.0
                } else {
                    self.energy_j * class_tokens as f64 / total_tokens as f64
                };
                ClassSummary {
                    class_id: cid,
                    name: class.name.clone(),
                    requests: of_class.len(),
                    attainment: if of_class.is_empty() {
                        0.0
                    } else {
                        ok as f64 / of_class.len() as f64
                    },
                    goodput_rps: ok as f64 / span,
                    mean_ttft: mean(&ttfts),
                    p99_ttft: percentile(&ttfts, 99.0),
                    mean_tpot: mean(&tpots),
                    joule_per_good_tok: (good_tokens > 0 && class_energy > 0.0)
                        .then(|| class_energy / good_tokens as f64),
                }
            })
            .collect()
    }

    pub fn summary(&self) -> MetricsSummary {
        let ttfts: Vec<f64> = self.per_request.iter().map(|m| m.ttft).collect();
        let tpots: Vec<f64> =
            self.per_request.iter().filter(|m| m.output_tokens > 1).map(|m| m.tpot).collect();
        let e2es: Vec<f64> = self.per_request.iter().map(|m| m.e2e).collect();
        let tokens: usize = self.per_request.iter().map(|m| m.output_tokens).sum();
        let span = self.makespan.max(1e-12);
        MetricsSummary {
            requests: self.per_request.len(),
            mean_ttft: mean(&ttfts),
            p50_ttft: percentile(&ttfts, 50.0),
            p99_ttft: percentile(&ttfts, 99.0),
            mean_tpot: mean(&tpots),
            p50_tpot: percentile(&tpots, 50.0),
            p99_tpot: percentile(&tpots, 99.0),
            mean_e2e: mean(&e2es),
            throughput_tps: tokens as f64 / span,
            throughput_rps: self.per_request.len() as f64 / span,
            energy_j: self.energy_j,
            joule_per_tok: if tokens == 0 { 0.0 } else { self.energy_j / tokens as f64 },
            classes: Vec::new(),
        }
    }

    /// [`summary`](Self::summary) plus the per-class breakdown under the
    /// deployment's declared classes.
    pub fn summary_for(&self, classes: &ClassSet) -> MetricsSummary {
        let mut s = self.summary();
        s.classes = self.class_breakdown(classes);
        s
    }

    /// Rename one recorded completion. `serving::chaos` uses this when a
    /// hedge copy wins the race: the completion was recorded under the
    /// tagged hedge id and is re-attributed to the primary request, so
    /// per-request histories never show a synthetic id and conservation
    /// accounting stays by-original-request. No-op if `from` is absent.
    pub fn relabel(&mut self, from: RequestId, to: RequestId) {
        debug_assert!(
            !self.per_request.iter().any(|m| m.id == to),
            "relabel target {to} already has a completion — duplicate hedge finish?"
        );
        if let Some(m) = self.per_request.iter_mut().find(|m| m.id == from) {
            m.id = to;
        }
    }

    /// SLO-compliant completions per second, bucketed by completion time
    /// over `[0, makespan)` — the goodput-over-time curve the chaos
    /// experiment plots and [`recovery`](Self::recovery) analyzes.
    /// Completions at exactly `makespan` fold into the last bucket.
    pub fn goodput_timeline(&self, classes: &ClassSet, bucket_s: f64) -> Vec<f64> {
        assert!(bucket_s.is_finite() && bucket_s > 0.0, "bucket must be positive");
        let n = ((self.makespan / bucket_s).ceil() as usize).max(1);
        let mut buckets = vec![0usize; n];
        for m in self.compliant(classes) {
            let i = ((m.finish / bucket_s) as usize).min(n - 1);
            buckets[i] += 1;
        }
        buckets.into_iter().map(|c| c as f64 / bucket_s).collect()
    }

    /// Degradation-and-recovery analysis around a fault at `fault_t`:
    /// baseline goodput from the buckets fully before the fault, then
    /// dip depth, dip area and time back to [`RECOVERY_FRACTION`] of
    /// baseline measured over the buckets at/after it.
    pub fn recovery(&self, classes: &ClassSet, fault_t: f64, bucket_s: f64) -> RecoveryMetrics {
        let timeline = self.goodput_timeline(classes, bucket_s);
        let pre: Vec<f64> = timeline
            .iter()
            .copied()
            .enumerate()
            .filter(|(i, _)| (*i as f64 + 1.0) * bucket_s <= fault_t)
            .map(|(_, g)| g)
            .collect();
        let baseline = mean(&pre); // 0.0 when no full pre-fault bucket
        let post: Vec<(usize, f64)> = timeline
            .iter()
            .copied()
            .enumerate()
            .filter(|(i, _)| (*i as f64 + 1.0) * bucket_s > fault_t)
            .collect();
        let min_post = post.iter().map(|(_, g)| *g).fold(f64::INFINITY, f64::min);
        let dip_depth = if post.is_empty() { 0.0 } else { (baseline - min_post).max(0.0) };
        let dip_area: f64 =
            post.iter().map(|(_, g)| (baseline - g).max(0.0) * bucket_s).sum();
        let recovery_time_s = post
            .iter()
            .find(|(_, g)| *g >= RECOVERY_FRACTION * baseline)
            .map(|(i, _)| ((*i as f64 + 1.0) * bucket_s - fault_t).max(0.0));
        RecoveryMetrics { baseline_rps: baseline, dip_depth, dip_area, recovery_time_s }
    }
}

/// A post-fault bucket counts as "recovered" once its goodput is back to
/// this fraction of the pre-fault baseline (full recovery to 1.0 is
/// noise-sensitive: a single boundary-straddling completion flips it).
pub const RECOVERY_FRACTION: f64 = 0.9;

/// Goodput degradation and recovery around one fault window — the
/// recovery-SLO surface of `repro run chaos-sweep` (time-to-recover,
/// how deep the dip went, and its integrated request deficit).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryMetrics {
    /// Mean goodput (req/s) over the buckets fully before the fault.
    pub baseline_rps: f64,
    /// Worst post-fault goodput shortfall vs baseline (req/s, >= 0).
    pub dip_depth: f64,
    /// Integrated shortfall over post-fault buckets (requests "lost to
    /// the dip" — delayed past their bucket, not dropped).
    pub dip_area: f64,
    /// Time from the fault until the first bucket back at
    /// [`RECOVERY_FRACTION`] of baseline; `None` if the run ended first.
    pub recovery_time_s: Option<f64>,
}

impl RecoveryMetrics {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("baseline_req_per_s", Json::Num(self.baseline_rps)),
            ("dip_depth_req_per_s", Json::Num(self.dip_depth)),
            ("dip_area_requests", Json::Num(self.dip_area)),
            (
                "recovery_time_s",
                match self.recovery_time_s {
                    Some(t) => Json::Num(t),
                    None => Json::Null,
                },
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::qos::TrafficClass;
    use crate::serving::request::{Phase, Request};

    fn finished_seq(arrival: f64, first: f64, finish: f64, gen: usize) -> Sequence {
        let mut s = Sequence::new(Request::new(1, 10, gen, arrival));
        s.phase = Phase::Finished;
        s.generated = gen;
        s.first_token_time = Some(first);
        s.finish_time = Some(finish);
        s
    }

    fn m(id: RequestId, ttft: f64) -> RequestMetrics {
        RequestMetrics {
            id,
            ttft,
            tpot: 0.01,
            e2e: 1.0,
            finish: id as f64,
            output_tokens: 100,
            class_id: 0,
        }
    }

    #[test]
    fn request_metrics_math() {
        let rm = RequestMetrics::from_sequence(&finished_seq(1.0, 1.5, 2.5, 11));
        assert_eq!(rm.id, 1);
        assert!((rm.ttft - 0.5).abs() < 1e-12);
        assert!((rm.tpot - 0.1).abs() < 1e-12);
        assert!((rm.e2e - 1.5).abs() < 1e-12);
        assert_eq!(rm.class_id, 0, "untagged requests land in the default class");
    }

    #[test]
    fn class_id_flows_from_request_to_metrics() {
        let mut s = finished_seq(0.0, 0.2, 0.4, 3);
        s.req = s.req.clone().with_class(2);
        assert_eq!(RequestMetrics::from_sequence(&s).class_id, 2);
    }

    #[test]
    fn single_token_has_zero_tpot() {
        let rm = RequestMetrics::from_sequence(&finished_seq(0.0, 0.2, 0.2, 1));
        assert_eq!(rm.tpot, 0.0);
    }

    #[test]
    fn summary_aggregates() {
        let mut c = MetricsCollector::default();
        for i in 0..10 {
            c.record(m(i, 0.1 * (i + 1) as f64));
        }
        c.makespan = 10.0;
        let s = c.summary();
        assert_eq!(s.requests, 10);
        assert!((s.mean_ttft - 0.55).abs() < 1e-9);
        assert!((s.throughput_tps - 100.0).abs() < 1e-9);
        assert!((s.throughput_rps - 1.0).abs() < 1e-9);
        assert!(s.p99_ttft >= s.mean_ttft);
        assert!(s.p50_ttft <= s.p99_ttft);
        assert_eq!(c.output_tokens(), 1000);
        assert!(s.classes.is_empty(), "plain summary carries no class breakdown");
    }

    #[test]
    fn merge_concatenates_and_takes_max_makespan() {
        let mut a = MetricsCollector::default();
        a.record(m(0, 0.1));
        a.makespan = 4.0;
        let mut b = MetricsCollector::default();
        b.record(m(1, 0.3));
        b.record(m(2, 0.2));
        b.makespan = 9.0;
        a.merge(&b);
        assert_eq!(a.len(), 3);
        assert_eq!(a.makespan, 9.0);
        // Fleet tokens = sum of replica tokens.
        assert_eq!(a.output_tokens(), 300);
    }

    #[test]
    fn summary_json_has_every_field() {
        let mut c = MetricsCollector::default();
        c.record(m(0, 0.25));
        c.makespan = 2.0;
        let j = crate::util::json::Json::parse(&c.summary().to_json().dump()).unwrap();
        assert_eq!(j.get("requests").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("mean_ttft_s").unwrap().as_f64(), Some(0.25));
        assert_eq!(j.get("throughput_tok_per_s").unwrap().as_f64(), Some(50.0));
        assert_eq!(j.get("throughput_req_per_s").unwrap().as_f64(), Some(0.5));
        assert!(j.get("classes").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn energy_merges_and_summarizes() {
        let mut a = MetricsCollector::default();
        a.record(m(0, 0.1)); // 100 output tokens
        a.makespan = 2.0;
        a.energy_j = 500.0;
        let mut b = MetricsCollector::default();
        b.record(m(1, 0.5));
        b.energy_j = 300.0;
        a.merge(&b);
        assert_eq!(a.energy_j, 800.0);
        let s = a.summary();
        assert_eq!(s.energy_j, 800.0);
        assert!((s.joule_per_tok - 4.0).abs() < 1e-12);
        let j = s.to_json();
        assert_eq!(j.get("energy_j").unwrap().as_f64(), Some(800.0));
        assert_eq!(j.get("joule_per_tok").unwrap().as_f64(), Some(s.joule_per_tok));
        // J per *good* token under a TTFT SLO only request 0 meets.
        assert_eq!(a.energy_per_good_token(&ClassSet::scalar(0.2, 1.0)), Some(8.0));
        // Nobody compliant -> no finite cost.
        assert_eq!(a.energy_per_good_token(&ClassSet::scalar(0.01, 1.0)), None);
        // No energy modeled -> None.
        assert_eq!(
            MetricsCollector::default().energy_per_good_token(&ClassSet::default()),
            None
        );
    }

    #[test]
    fn goodput_and_attainment() {
        let mut c = MetricsCollector::default();
        c.record(m(0, 0.1)); // compliant (ttft <= 0.2)
        c.record(m(1, 0.5)); // violates TTFT SLO
        c.makespan = 2.0;
        let classes = ClassSet::scalar(0.2, 0.05);
        assert!((c.goodput(&classes) - 0.5).abs() < 1e-12);
        assert!((c.attainment(&classes) - 0.5).abs() < 1e-12);
        // Tightening the TPOT SLO below 0.01 kills both.
        assert_eq!(c.goodput(&ClassSet::scalar(0.2, 0.001)), 0.0);
    }

    #[test]
    fn per_class_compliance_uses_each_requests_own_slo() {
        // Two classes with very different TTFT SLOs; one request each at
        // the same measured TTFT: tight class fails, loose class passes.
        let classes = ClassSet::new(vec![
            TrafficClass::new("tight", 1, 0.2, 0.05, 2.0),
            TrafficClass::new("loose", 0, 2.0, 0.05, 1.0),
        ])
        .unwrap();
        let mut c = MetricsCollector::default();
        c.record(RequestMetrics { class_id: 0, ..m(0, 0.5) });
        c.record(RequestMetrics { class_id: 1, ..m(1, 0.5) });
        c.makespan = 1.0;
        assert!((c.attainment(&classes) - 0.5).abs() < 1e-12);
        assert!((c.goodput(&classes) - 1.0).abs() < 1e-12);
        let per = c.class_breakdown(&classes);
        assert_eq!(per.len(), 2);
        assert_eq!((per[0].requests, per[1].requests), (1, 1));
        assert_eq!(per[0].attainment, 0.0);
        assert_eq!(per[1].attainment, 1.0);
        assert_eq!(per[1].goodput_rps, 1.0);
        assert_eq!(per[0].name, "tight");
        // Weighted attainment: (2*0 + 1*1) / 3.
        assert!((c.weighted_attainment(&classes) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_attainment_degenerates_to_plain_for_single_class() {
        let mut c = MetricsCollector::default();
        c.record(m(0, 0.1));
        c.record(m(1, 5.0));
        c.record(m(2, 0.2));
        c.makespan = 1.0;
        let classes = ClassSet::default();
        // Exact: a single weight-1.0 class multiplies and divides by 1.0.
        assert_eq!(c.weighted_attainment(&classes), c.attainment(&classes));
    }

    #[test]
    fn class_breakdown_attributes_energy_by_token_share() {
        let classes = ClassSet::new(vec![
            TrafficClass::new("a", 0, 1.0, 0.1, 1.0),
            TrafficClass::new("b", 0, 1.0, 0.1, 1.0),
        ])
        .unwrap();
        let mut c = MetricsCollector::default();
        // Class 0: 300 tokens, class 1: 100 tokens, all compliant.
        c.record(RequestMetrics { class_id: 0, output_tokens: 300, ..m(0, 0.1) });
        c.record(RequestMetrics { class_id: 1, output_tokens: 100, ..m(1, 0.1) });
        c.makespan = 1.0;
        c.energy_j = 400.0;
        let per = c.class_breakdown(&classes);
        // 400 J x (300/400) / 300 good = 1 J/tok; 400 x (100/400) / 100 = 1.
        assert_eq!(per[0].joule_per_good_tok, Some(1.0));
        assert_eq!(per[1].joule_per_good_tok, Some(1.0));
        // Class summaries reach JSON (None -> null).
        let j = Json::parse(&per[0].to_json().dump()).unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("a"));
        assert_eq!(j.get("joule_per_good_tok").unwrap().as_f64(), Some(1.0));
        let empty = MetricsCollector::default();
        let none = &empty.class_breakdown(&classes)[0];
        assert_eq!(none.joule_per_good_tok, None);
        assert_eq!(
            none.to_json().get("joule_per_good_tok"),
            Some(&Json::Null)
        );
    }

    #[test]
    fn relabel_reattributes_a_hedge_completion() {
        let mut c = MetricsCollector::default();
        let hedge_id = 5 | crate::serving::chaos::HEDGE_BIT;
        c.record(m(hedge_id, 0.1));
        c.record(m(2, 0.2));
        c.relabel(hedge_id, 5);
        let ids: Vec<RequestId> = c.per_request().iter().map(|m| m.id).collect();
        assert_eq!(ids, vec![5, 2]);
        c.relabel(999, 1000); // absent: no-op
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn goodput_timeline_buckets_compliant_completions() {
        let mut c = MetricsCollector::default();
        // finish = id (helper m): 0,1,2 in early buckets, 9 at makespan.
        for (id, ttft) in [(0, 0.1), (1, 0.1), (2, 0.9), (9, 0.1)] {
            c.record(m(id, ttft));
        }
        c.makespan = 10.0;
        let classes = ClassSet::scalar(0.2, 0.05); // ttft 0.9 violates
        let tl = c.goodput_timeline(&classes, 2.0);
        assert_eq!(tl.len(), 5);
        // Bucket [0,2): ids 0,1 -> 2 compliant / 2 s; id 2 non-compliant;
        // id 9 finishes at t=9 -> the last bucket [8,10).
        assert_eq!(tl, vec![1.0, 0.0, 0.0, 0.0, 0.5]);
    }

    #[test]
    fn recovery_measures_dip_and_return_to_baseline() {
        let mut c = MetricsCollector::default();
        // 1 compliant completion per second until t=4, nothing in [4,6),
        // then 1/s again from t=6 (finish = id here).
        for id in [0, 1, 2, 3, 6, 7, 8, 9] {
            c.record(m(id, 0.1));
        }
        c.makespan = 10.0;
        let classes = ClassSet::scalar(0.2, 0.05);
        let r = c.recovery(&classes, 4.0, 1.0);
        assert!((r.baseline_rps - 1.0).abs() < 1e-12);
        assert!((r.dip_depth - 1.0).abs() < 1e-12, "two empty buckets hit 0 rps");
        // Empty buckets [4,5) and [5,6) each contribute 1.0 x 1 s.
        assert!((r.dip_area - 2.0).abs() < 1e-12);
        // First bucket back at >= 0.9 baseline is [6,7) -> ends 3 s after
        // the fault.
        assert_eq!(r.recovery_time_s, Some(3.0));
        // A fault the run never recovers from reports None.
        let mut dead = MetricsCollector::default();
        for id in 0..4 {
            dead.record(m(id, 0.1));
        }
        dead.makespan = 10.0;
        assert_eq!(dead.recovery(&classes, 4.0, 1.0).recovery_time_s, None);
        let j = r.to_json();
        assert_eq!(j.get("recovery_time_s").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("dip_area_requests").unwrap().as_f64(), Some(r.dip_area));
    }

    #[test]
    fn summary_for_carries_the_breakdown_into_json() {
        let mut c = MetricsCollector::default();
        c.record(m(0, 0.25));
        c.makespan = 2.0;
        let s = c.summary_for(&ClassSet::default());
        assert_eq!(s.classes.len(), 1);
        let j = Json::parse(&s.to_json().dump()).unwrap();
        let arr = j.get("classes").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("default"));
        assert_eq!(arr[0].get("requests").unwrap().as_usize(), Some(1));
        assert_eq!(arr[0].get("attainment").unwrap().as_f64(), Some(1.0));
    }
}
