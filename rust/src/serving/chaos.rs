//! Seeded fault injection for the cluster simulator — the chaos layer of
//! the serving stack (`serving::cluster` consumes it).
//!
//! A [`FaultSchedule`] is a deterministic, JSON-loadable list of
//! [`Fault`]s: replica crashes (with a restart after a down time),
//! stragglers (a slow-clock factor over an interval) and preemption
//! storms (forced preemptions injected at an instant). The schedule is
//! *data*, not behavior: `ClusterSim::install_chaos` expands it into
//! timestamped [`ControlKind`] events on a third min-heap alongside the
//! arrival and replica-wake heaps, so the same pinned-ordering event
//! core that made indexed runs bitwise-equal to the scan-loop oracle
//! also makes every chaos run reproducible from its schedule + workload
//! seed alone. An empty schedule contributes no events and therefore
//! replays the fault-free run bitwise — the control arm of every
//! recovery claim (`repro run chaos-sweep --check`).
//!
//! Hedged requests ride the same control heap: when hedging is enabled
//! (`ServingConfig::hedge_after_s > 0`) every delivery also schedules a
//! [`ControlKind::HedgeCheck`]; if the primary still has no first token
//! by then, a clone tagged with [`HEDGE_BIT`] races it on a *different*
//! replica, first completion wins, the loser is cancelled without
//! double-counting tokens. [`ChaosStats`] ledgers every injected event
//! and its consequences so the conservation claim — submitted ==
//! completed + deliberately shed, zero silently lost — is checkable per
//! run.

use crate::serving::request::RequestId;
use crate::util::json::Json;
use crate::util::prng::Rng;

/// High bit tagging the cloned copy of a hedged request. Request ids are
/// sequence numbers from the workload generators (nowhere near 2^63), so
/// the tag can never collide with a real id; `hedge_primary` recovers
/// the original id from either copy.
pub const HEDGE_BIT: u64 = 1 << 63;

/// The original request id behind either copy of a hedge pair.
pub fn hedge_primary(id: RequestId) -> RequestId {
    id & !HEDGE_BIT
}

/// Whether `id` names the cloned (hedge) copy rather than the primary.
pub fn is_hedge(id: RequestId) -> bool {
    id & HEDGE_BIT != 0
}

/// One injected fault. Times are simulation seconds, replicas are fleet
/// indices (validated against the deployment before installation).
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// `replica` dies at `at`: its queued + in-flight requests are
    /// requeued through the router (re-prefilling from scratch — no KV
    /// replication is assumed), its prefix-cache residency is
    /// invalidated, and it rejoins the fleet `down_s` later.
    Crash { replica: usize, at: f64, down_s: f64 },
    /// `replica`'s step durations are dilated by `factor` over
    /// `[from, until)` — the router's cost weight and the per-class
    /// attainment EWMA both see the slowdown honestly.
    Straggler { replica: usize, from: f64, until: f64, factor: f64 },
    /// `count` forced preemptions hit `replica`'s scheduler at `at`
    /// (victims re-prefill; models a host-side memory/driver hiccup).
    PreemptStorm { replica: usize, at: f64, count: usize },
}

impl Fault {
    /// The replica this fault targets.
    pub fn replica(&self) -> usize {
        match *self {
            Fault::Crash { replica, .. }
            | Fault::Straggler { replica, .. }
            | Fault::PreemptStorm { replica, .. } => replica,
        }
    }

    /// `[start, end)` window the fault is active over (instantaneous
    /// faults report a zero-length window) — the plot-shading export.
    pub fn window(&self) -> (f64, f64) {
        match *self {
            Fault::Crash { at, down_s, .. } => (at, at + down_s),
            Fault::Straggler { from, until, .. } => (from, until),
            Fault::PreemptStorm { at, .. } => (at, at),
        }
    }

    fn kind_name(&self) -> &'static str {
        match self {
            Fault::Crash { .. } => "crash",
            Fault::Straggler { .. } => "straggler",
            Fault::PreemptStorm { .. } => "preempt_storm",
        }
    }

    fn validate(&self, replicas: usize) -> anyhow::Result<()> {
        let r = self.replica();
        if r >= replicas {
            anyhow::bail!("fault targets replica {r} but the fleet has {replicas}");
        }
        match *self {
            Fault::Crash { at, down_s, .. } => {
                if !(at.is_finite() && at >= 0.0) {
                    anyhow::bail!("crash 'at' must be finite and >= 0");
                }
                if !(down_s.is_finite() && down_s > 0.0) {
                    anyhow::bail!("crash 'down_s' must be finite and > 0");
                }
            }
            Fault::Straggler { from, until, factor, .. } => {
                if !(from.is_finite() && from >= 0.0 && until.is_finite() && until > from) {
                    anyhow::bail!("straggler window must satisfy 0 <= from < until (finite)");
                }
                if !(factor.is_finite() && factor >= 1.0) {
                    anyhow::bail!("straggler 'factor' must be finite and >= 1");
                }
            }
            Fault::PreemptStorm { at, count, .. } => {
                if !(at.is_finite() && at >= 0.0) {
                    anyhow::bail!("preempt_storm 'at' must be finite and >= 0");
                }
                if count == 0 {
                    anyhow::bail!("preempt_storm 'count' must be > 0");
                }
            }
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        match *self {
            Fault::Crash { replica, at, down_s } => Json::obj(vec![
                ("kind", Json::Str("crash".into())),
                ("replica", Json::Num(replica as f64)),
                ("at", Json::Num(at)),
                ("down_s", Json::Num(down_s)),
            ]),
            Fault::Straggler { replica, from, until, factor } => Json::obj(vec![
                ("kind", Json::Str("straggler".into())),
                ("replica", Json::Num(replica as f64)),
                ("from", Json::Num(from)),
                ("until", Json::Num(until)),
                ("factor", Json::Num(factor)),
            ]),
            Fault::PreemptStorm { replica, at, count } => Json::obj(vec![
                ("kind", Json::Str("preempt_storm".into())),
                ("replica", Json::Num(replica as f64)),
                ("at", Json::Num(at)),
                ("count", Json::Num(count as f64)),
            ]),
        }
    }

    fn from_json(j: &Json) -> anyhow::Result<Fault> {
        let kind = j
            .get("kind")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("fault needs a string 'kind'"))?;
        let num = |key: &str| -> anyhow::Result<f64> {
            j.get(key)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("fault '{kind}' needs numeric '{key}'"))
        };
        let replica = j
            .get("replica")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("fault '{kind}' needs integer 'replica'"))?;
        Ok(match kind {
            "crash" => Fault::Crash { replica, at: num("at")?, down_s: num("down_s")? },
            "straggler" => Fault::Straggler {
                replica,
                from: num("from")?,
                until: num("until")?,
                factor: num("factor")?,
            },
            "preempt_storm" => Fault::PreemptStorm {
                replica,
                at: num("at")?,
                count: num("count")? as usize,
            },
            other => anyhow::bail!("unknown fault kind '{other}'"),
        })
    }
}

/// A deterministic list of faults to inject into one run. The schedule
/// is pure data: two `ClusterSim` runs over the same schedule, config
/// and workload seed replay bit-for-bit.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultSchedule {
    pub faults: Vec<Fault>,
}

impl FaultSchedule {
    /// The no-chaos schedule — installs zero control events, so the run
    /// is bitwise-equal to never calling `install_chaos` at all.
    pub fn empty() -> FaultSchedule {
        FaultSchedule::default()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Builder-style append.
    pub fn with(mut self, fault: Fault) -> FaultSchedule {
        self.faults.push(fault);
        self
    }

    /// Every fault must target a real replica and carry sane numbers.
    pub fn validate(&self, replicas: usize) -> anyhow::Result<()> {
        for f in &self.faults {
            f.validate(replicas)?;
        }
        Ok(())
    }

    /// Expand to timestamped control events (schedule order preserved for
    /// equal-time faults via the caller's enqueue sequence numbers).
    pub fn control_events(&self) -> Vec<(f64, ControlKind)> {
        let mut out = Vec::with_capacity(self.faults.len() * 2);
        for f in &self.faults {
            match *f {
                Fault::Crash { replica, at, down_s } => {
                    out.push((at, ControlKind::CrashStart { replica }));
                    out.push((at + down_s, ControlKind::Restart { replica }));
                }
                Fault::Straggler { replica, from, until, factor } => {
                    out.push((from, ControlKind::StragglerStart { replica, factor }));
                    out.push((until, ControlKind::StragglerEnd { replica }));
                }
                Fault::PreemptStorm { replica, at, count } => {
                    out.push((at, ControlKind::Storm { replica, count }));
                }
            }
        }
        out
    }

    /// `(start, end, kind)` shading windows, for the harness artifact and
    /// the goodput-timeline plot.
    pub fn windows(&self) -> Vec<(f64, f64, &'static str)> {
        self.faults
            .iter()
            .map(|f| {
                let (a, b) = f.window();
                (a, b, f.kind_name())
            })
            .collect()
    }

    /// A seeded random schedule over `replicas` replicas inside
    /// `[0, horizon_s)` — the property-test generator. Deterministic in
    /// `seed`; 1..=3 faults, every one valid by construction.
    pub fn random(seed: u64, replicas: usize, horizon_s: f64) -> FaultSchedule {
        assert!(replicas > 0 && horizon_s > 0.0);
        let mut rng = Rng::new(seed ^ 0xC0A5_F00D);
        let n = 1 + rng.below(3) as usize;
        let mut s = FaultSchedule::empty();
        for _ in 0..n {
            let replica = rng.below(replicas as u64) as usize;
            let at = rng.f64() * horizon_s * 0.6;
            s.faults.push(match rng.below(3) {
                // Crashes only make sense with a peer to fail over to;
                // single-replica draws degrade to storms.
                0 if replicas > 1 => Fault::Crash {
                    replica,
                    at,
                    down_s: 0.2 + rng.f64() * horizon_s * 0.3,
                },
                1 => Fault::Straggler {
                    replica,
                    from: at,
                    until: at + 0.2 + rng.f64() * horizon_s * 0.4,
                    factor: 1.5 + rng.f64() * 4.0,
                },
                _ => Fault::PreemptStorm { replica, at, count: 1 + rng.below(6) as usize },
            });
        }
        s
    }

    /// Parse `{"faults": [...]}` (accepts a bare array too).
    pub fn from_json(s: &str) -> anyhow::Result<FaultSchedule> {
        let j = Json::parse(s).map_err(|e| anyhow::anyhow!("{e}"))?;
        let arr = match j.get("faults") {
            Some(v) => v
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("'faults' must be an array"))?
                .to_vec(),
            None => j
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("want {{\"faults\": [...]}} or a bare array"))?
                .to_vec(),
        };
        let faults =
            arr.iter().map(Fault::from_json).collect::<anyhow::Result<Vec<Fault>>>()?;
        Ok(FaultSchedule { faults })
    }

    pub fn to_json(&self) -> String {
        Json::obj(vec![(
            "faults",
            Json::Arr(self.faults.iter().map(|f| f.to_json()).collect()),
        )])
        .dump()
    }
}

/// A timestamped chaos control event on the cluster's third heap. The
/// first five kinds come from expanding a [`FaultSchedule`]; hedge
/// checks are scheduled per-delivery by the cluster itself.
#[derive(Debug, Clone, PartialEq)]
pub enum ControlKind {
    /// Replica dies now (skipped if already down or last one standing).
    CrashStart { replica: usize },
    /// Replica rejoins the fleet (no-op unless it is down).
    Restart { replica: usize },
    /// Replica's step durations dilate by `factor` from now on.
    StragglerStart { replica: usize, factor: f64 },
    /// Replica's clock runs true again.
    StragglerEnd { replica: usize },
    /// `count` forced preemptions on the replica's scheduler, now.
    Storm { replica: usize, count: usize },
    /// If request `id` still has no first token, clone it to a second
    /// replica (first completion wins, loser cancelled).
    HedgeCheck { id: RequestId },
}

/// Ledger of everything the chaos engine injected and what it cost —
/// the per-run evidence behind the conservation and recovery claims.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ChaosStats {
    /// Crashes that fired (replica actually went down).
    pub crashes: u64,
    /// Crash events skipped (already down, or last active replica).
    pub crashes_skipped: u64,
    /// Restarts that brought a down replica back.
    pub restarts: u64,
    /// Requests evacuated from crashed replicas and requeued.
    pub requeued_by_crash: u64,
    /// Straggler windows that started.
    pub straggler_windows: u64,
    /// Preemption storms that fired.
    pub storms: u64,
    /// Forced preemptions actually applied by storms.
    pub forced_preemptions: u64,
    /// Hedge clones launched onto a second replica.
    pub hedges_launched: u64,
    /// Hedge races the *clone* won (primary was cancelled).
    pub hedges_won: u64,
    /// Hedge copies cancelled (race losers + crash dissolutions).
    pub hedges_cancelled: u64,
    /// Priority-0 requests rejected by admission control under overload.
    pub shed: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn three() -> FaultSchedule {
        FaultSchedule::empty()
            .with(Fault::Crash { replica: 0, at: 3.0, down_s: 2.0 })
            .with(Fault::Straggler { replica: 1, from: 2.0, until: 6.0, factor: 4.0 })
            .with(Fault::PreemptStorm { replica: 0, at: 4.0, count: 8 })
    }

    #[test]
    fn json_roundtrip() {
        let s = three();
        let back = FaultSchedule::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        // Bare arrays parse too.
        let bare = FaultSchedule::from_json(
            r#"[{"kind": "crash", "replica": 1, "at": 0.5, "down_s": 1.0}]"#,
        )
        .unwrap();
        assert_eq!(bare.faults.len(), 1);
        assert_eq!(bare.faults[0].replica(), 1);
    }

    #[test]
    fn bad_json_rejected() {
        assert!(FaultSchedule::from_json("not json").is_err());
        assert!(FaultSchedule::from_json(r#"{"faults": "crash"}"#).is_err());
        assert!(FaultSchedule::from_json(r#"[{"kind": "meteor", "replica": 0}]"#).is_err());
        assert!(FaultSchedule::from_json(r#"[{"kind": "crash", "replica": 0}]"#).is_err());
    }

    #[test]
    fn validate_checks_targets_and_numbers() {
        let s = three();
        s.validate(2).unwrap();
        assert!(s.validate(1).is_err(), "replica 1 out of a 1-wide fleet");
        let bad = FaultSchedule::empty().with(Fault::Crash { replica: 0, at: 1.0, down_s: 0.0 });
        assert!(bad.validate(1).is_err());
        let bad =
            FaultSchedule::empty().with(Fault::Straggler { replica: 0, from: 2.0, until: 2.0, factor: 3.0 });
        assert!(bad.validate(1).is_err());
        let bad =
            FaultSchedule::empty().with(Fault::Straggler { replica: 0, from: 0.0, until: 1.0, factor: 0.5 });
        assert!(bad.validate(1).is_err());
        let bad = FaultSchedule::empty().with(Fault::PreemptStorm { replica: 0, at: 1.0, count: 0 });
        assert!(bad.validate(1).is_err());
    }

    #[test]
    fn control_events_pair_up() {
        let ev = three().control_events();
        assert_eq!(ev.len(), 5, "crash + restart, start + end, storm");
        assert!(matches!(ev[0], (t, ControlKind::CrashStart { replica: 0 }) if t == 3.0));
        assert!(matches!(ev[1], (t, ControlKind::Restart { replica: 0 }) if t == 5.0));
        assert!(matches!(ev[3], (t, ControlKind::StragglerEnd { replica: 1 }) if t == 6.0));
        assert!(FaultSchedule::empty().control_events().is_empty());
    }

    #[test]
    fn windows_expose_shading_ranges() {
        let w = three().windows();
        assert_eq!(w[0], (3.0, 5.0, "crash"));
        assert_eq!(w[1], (2.0, 6.0, "straggler"));
        assert_eq!(w[2], (4.0, 4.0, "preempt_storm"));
    }

    #[test]
    fn random_is_deterministic_and_valid() {
        for seed in 0..50u64 {
            for replicas in 1..4usize {
                let a = FaultSchedule::random(seed, replicas, 10.0);
                let b = FaultSchedule::random(seed, replicas, 10.0);
                assert_eq!(a, b, "same seed must replay the same schedule");
                a.validate(replicas).unwrap();
                assert!(!a.is_empty());
                if replicas == 1 {
                    assert!(
                        !a.faults.iter().any(|f| matches!(f, Fault::Crash { .. })),
                        "single-replica schedules never crash the only replica"
                    );
                }
            }
        }
        assert_ne!(
            FaultSchedule::random(1, 3, 10.0),
            FaultSchedule::random(2, 3, 10.0),
            "different seeds should (generically) differ"
        );
    }

    #[test]
    fn hedge_bit_tags_and_recovers() {
        assert!(!is_hedge(17));
        let clone = 17 | HEDGE_BIT;
        assert!(is_hedge(clone));
        assert_eq!(hedge_primary(clone), 17);
        assert_eq!(hedge_primary(17), 17);
    }
}
