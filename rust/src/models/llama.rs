//! Llama-3.1 serving cost model (Fig 12/13): per-layer prefill GEMMs via
//! the device matrix-engine simulators, decode steps via a
//! memory-bandwidth-utilization (MBU) model + the PagedAttention operator,
//! tensor-parallel AllReduce via the collective simulator, and energy via
//! the activity-based power model.
//!
//! Calibration notes: on decode (weight streaming), optimum-habana/Gaudi
//! sustains a higher fraction of its pins than TensorRT-LLM/A100 at these
//! shapes — this, plus the MME's shape-adaptive utilization on prefill, is
//! what pushes Gaudi's end-to-end advantage beyond the raw 1.2×/1.4×
//! hardware ratios (paper §3.5, "an even greater speedup due to its
//! superior compute utilization across various GEMM shapes").

use crate::config::DeviceKind;
use crate::ops::attention::{self, PagedAttnImpl, PagedAttnWork};
use crate::sim::collective::CollectiveModel;
use crate::sim::device::Device;
use crate::sim::power::{Activity, PowerModel};
use crate::sim::Dtype;

/// Llama-3.1 architecture hyper-parameters (Table 3).
#[derive(Debug, Clone, Copy)]
pub struct LlamaConfig {
    pub name: &'static str,
    pub layers: usize,
    pub hidden: usize,
    pub intermediate: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
}

impl LlamaConfig {
    pub fn llama31_8b() -> Self {
        LlamaConfig {
            name: "Llama-3.1-8B",
            layers: 32,
            hidden: 4096,
            intermediate: 14336,
            n_q_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            vocab: 128_256,
        }
    }

    pub fn llama31_70b() -> Self {
        LlamaConfig {
            name: "Llama-3.1-70B",
            layers: 80,
            hidden: 8192,
            intermediate: 28672,
            n_q_heads: 64,
            n_kv_heads: 8,
            head_dim: 128,
            vocab: 128_256,
        }
    }

    /// Parameter count (weights only).
    pub fn params(&self) -> f64 {
        let h = self.hidden as f64;
        let kv = (self.n_kv_heads * self.head_dim) as f64;
        let q = (self.n_q_heads * self.head_dim) as f64;
        let per_layer = h * (q + 2.0 * kv) // qkv proj
            + q * h                        // o proj
            + 3.0 * h * self.intermediate as f64; // gate/up/down
        self.layers as f64 * per_layer + 2.0 * h * self.vocab as f64
    }

    /// Weight bytes in BF16.
    pub fn weight_bytes(&self) -> f64 {
        self.params() * 2.0
    }

    /// KV-cache bytes per token (all layers).
    pub fn kv_bytes_per_token(&self) -> f64 {
        (self.layers * 2 * self.n_kv_heads * self.head_dim) as f64 * 2.0
    }
}

/// BF16 weight bytes each card of a `tp`-wide group must hold resident.
pub fn weight_bytes_per_card(cfg: &LlamaConfig, tp: usize) -> f64 {
    cfg.weight_bytes() / tp as f64
}

/// KV-cache tokens a `(kind, tp)` device group can hold once every card's
/// weight shard is resident: per card, `(hbm_capacity - weights/tp)` bytes
/// feed KV at `kv_bytes_per_token/tp` each (heads are sharded with the
/// GEMMs, so the group's token capacity is the per-card capacity). 0 means
/// the weights alone exceed HBM — the model does not fit at this width.
pub fn kv_token_capacity(cfg: &LlamaConfig, kind: DeviceKind, tp: usize) -> usize {
    let free = kind.spec().hbm_capacity - weight_bytes_per_card(cfg, tp);
    if free <= 0.0 {
        return 0;
    }
    (free / (cfg.kv_bytes_per_token() / tp as f64)) as usize
}

/// Whether the group can serve at all: weight shards fit and at least one
/// `min_tokens`-token sequence's KV fits beside them.
pub fn hbm_feasible(cfg: &LlamaConfig, kind: DeviceKind, tp: usize, min_tokens: usize) -> bool {
    kv_token_capacity(cfg, kind, tp) >= min_tokens.max(1)
}

/// Group-aware KV block budget: the number of `block_size`-token blocks
/// the group's post-weights HBM can hold (the `num_blocks` a sized
/// deployment should configure per replica).
pub fn kv_block_budget(cfg: &LlamaConfig, kind: DeviceKind, tp: usize, block_size: usize) -> usize {
    kv_token_capacity(cfg, kind, tp) / block_size.max(1)
}

/// Sustained fraction of HBM bandwidth during weight-streaming decode.
fn decode_mbu(kind: DeviceKind) -> f64 {
    match kind {
        DeviceKind::Gaudi2 => 0.88, // optimum-habana + HPU graphs
        DeviceKind::A100 => 0.72,   // TensorRT-LLM
    }
}

/// Fixed per-decode-step host/dispatch overhead (graphs replay).
fn step_overhead(kind: DeviceKind) -> f64 {
    match kind {
        DeviceKind::Gaudi2 => 25e-6,
        DeviceKind::A100 => 20e-6,
    }
}

/// One serving phase's time + average activity (for the power model).
#[derive(Debug, Clone, Copy)]
pub struct PhaseCost {
    pub time: f64,
    pub activity: Activity,
}

/// Prefill the whole batch (input length `in_len`) over `tp` devices.
pub fn prefill_cost(cfg: &LlamaConfig, kind: DeviceKind, batch: usize, in_len: usize, tp: usize) -> PhaseCost {
    let dev = Device::new(kind);
    let tokens = batch * in_len;
    let h = cfg.hidden;
    let q = cfg.n_q_heads * cfg.head_dim;
    let kv = cfg.n_kv_heads * cfg.head_dim;
    // Per-layer GEMMs, sharded over tp in the N (output-feature) dim.
    let qkv = dev.gemm(tokens, h, (q + 2 * kv) / tp, Dtype::Bf16);
    let o = dev.gemm(tokens, q / tp, h, Dtype::Bf16);
    let gate_up = dev.gemm(tokens, h, 2 * cfg.intermediate / tp, Dtype::Bf16);
    let down = dev.gemm(tokens, cfg.intermediate / tp, h, Dtype::Bf16);
    let attn = attention::prefill_attention_time(&dev, batch, in_len, cfg.n_q_heads / tp, cfg.head_dim);
    let ar_bytes = (tokens * h) as f64 * 2.0;
    let allreduce = 2.0 * CollectiveModel::for_device(kind).allreduce_time(tp, ar_bytes);
    let per_layer = qkv.time + o.time + gate_up.time + down.time + attn + allreduce;
    // LM head on the last token of each sequence.
    let lm_head = dev.gemm(batch, h, cfg.vocab / tp, Dtype::Bf16);
    let time = cfg.layers as f64 * per_layer + lm_head.time;
    let matrix_util =
        (qkv.utilization + o.utilization + gate_up.utilization + down.utilization) / 4.0;
    let active = (qkv.matrix_active_fraction
        + o.matrix_active_fraction
        + gate_up.matrix_active_fraction
        + down.matrix_active_fraction)
        / 4.0;
    PhaseCost {
        time,
        activity: Activity {
            matrix_util,
            matrix_active_fraction: active,
            vector_util: 0.25,
            hbm_util: 0.35,
            comm_util: if tp > 1 { 0.4 } else { 0.0 },
        },
    }
}

/// One decode step for the whole batch at KV length `kv_len`.
pub fn decode_step_cost(cfg: &LlamaConfig, kind: DeviceKind, batch: usize, kv_len: usize, tp: usize) -> PhaseCost {
    let spec = kind.spec();
    // Weight streaming: every parameter shard crosses HBM once per step.
    let weights = cfg.weight_bytes() / tp as f64;
    let mbu = decode_mbu(kind);
    let weight_time = weights / (spec.hbm_bandwidth * mbu);
    // PagedAttention over the KV cache (per layer × layers), sharded by
    // query heads across tp.
    let attn_work = PagedAttnWork {
        batch,
        kv_len: kv_len.max(1),
        padded_len: kv_len.max(1),
        n_q_heads: cfg.n_q_heads / tp,
        n_kv_heads: (cfg.n_kv_heads / tp).max(1),
        head_dim: cfg.head_dim,
        block_size: 128,
    };
    let attn_impl = match kind {
        DeviceKind::Gaudi2 => PagedAttnImpl::GaudiVllmOpt,
        DeviceKind::A100 => PagedAttnImpl::A100Paged,
    };
    let attn = cfg.layers as f64 * attention::run(attn_impl, attn_work).time;
    let ar_bytes = (batch * cfg.hidden) as f64 * 2.0;
    let allreduce =
        cfg.layers as f64 * 2.0 * CollectiveModel::for_device(kind).allreduce_time(tp, ar_bytes);
    let time = weight_time + attn + allreduce + step_overhead(kind);
    // Decode is a GEMV: the MME activates a narrow slice and power-gates
    // the rest (batch rows only); A100 keeps its full array clocked.
    let active_fraction = match kind {
        DeviceKind::Gaudi2 => ((batch as f64 / 256.0).min(1.0)).max(0.06),
        DeviceKind::A100 => 1.0,
    };
    PhaseCost {
        time,
        activity: Activity {
            matrix_util: 0.08,
            matrix_active_fraction: active_fraction,
            vector_util: 0.15,
            hbm_util: mbu * weight_time / time,
            comm_util: if tp > 1 { allreduce / time } else { 0.0 },
        },
    }
}

/// Full fixed-length serving episode: prefill `in_len`, decode `out_len`
/// tokens, batch `batch`, tensor-parallel over `tp` devices.
#[derive(Debug, Clone, Copy)]
pub struct ServingCost {
    pub prefill_time: f64,
    pub decode_time: f64,
    /// Joules over the episode (all `tp` devices).
    pub energy: f64,
    /// Average power per device, watts.
    pub avg_power: f64,
}

impl ServingCost {
    pub fn total_time(&self) -> f64 {
        self.prefill_time + self.decode_time
    }

    /// Output tokens per second.
    pub fn throughput(&self, batch: usize, out_len: usize) -> f64 {
        (batch * out_len) as f64 / self.total_time()
    }

    /// Output tokens per joule (the energy-efficiency metric of Fig 13).
    pub fn tokens_per_joule(&self, batch: usize, out_len: usize) -> f64 {
        (batch * out_len) as f64 / self.energy
    }
}

/// Serve one batch end-to-end with fixed input/output lengths (§3.5).
pub fn serve_fixed(
    cfg: &LlamaConfig,
    kind: DeviceKind,
    batch: usize,
    in_len: usize,
    out_len: usize,
    tp: usize,
) -> ServingCost {
    assert!(tp >= 1 && batch >= 1 && out_len >= 1);
    let power = PowerModel::for_device(kind);
    let pre = prefill_cost(cfg, kind, batch, in_len, tp);
    let mut decode_time = 0.0;
    let mut decode_energy = 0.0;
    // Integrate decode steps at a few KV-length sample points (the cost is
    // near-linear in kv_len, so sample + trapezoid is accurate and fast).
    let samples = 8.min(out_len);
    let mut prev_len = in_len;
    for s in 0..samples {
        let frac_hi = (s + 1) as f64 / samples as f64;
        let hi = in_len + (frac_hi * out_len as f64) as usize;
        let steps = (hi - prev_len).max(1) as f64;
        let mid = (prev_len + hi) / 2;
        let c = decode_step_cost(cfg, kind, batch, mid, tp);
        decode_time += steps * c.time;
        decode_energy += steps * c.time * power.power(c.activity) * tp as f64;
        prev_len = hi;
    }
    let energy = pre.time * power.power(pre.activity) * tp as f64 + decode_energy;
    ServingCost {
        prefill_time: pre.time,
        decode_time,
        energy,
        avg_power: energy / ((pre.time + decode_time) * tp as f64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    #[test]
    fn param_counts_match_model_names() {
        let p8 = LlamaConfig::llama31_8b().params();
        let p70 = LlamaConfig::llama31_70b().params();
        assert!((p8 / 1e9 - 8.0).abs() < 0.8, "8B params {}", p8 / 1e9);
        assert!((p70 / 1e9 - 70.0).abs() < 4.0, "70B params {}", p70 / 1e9);
    }

    /// The Fig 12(a) single-device grid: batch × output length, input 100.
    fn fig12_grid() -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        for &b in &[4usize, 16, 64] {
            for &o in &[25usize, 100, 400] {
                v.push((b, o));
            }
        }
        v
    }

    #[test]
    fn fig12a_single_device_speedup() {
        // Paper: Gaudi-2 avg 1.47x (max 1.70x) over A100 for 8B serving.
        let cfg = LlamaConfig::llama31_8b();
        let mut speedups = Vec::new();
        for (b, o) in fig12_grid() {
            let g = serve_fixed(&cfg, DeviceKind::Gaudi2, b, 100, o, 1);
            let a = serve_fixed(&cfg, DeviceKind::A100, b, 100, o, 1);
            speedups.push(a.total_time() / g.total_time());
        }
        let avg = mean(&speedups);
        let max = speedups.iter().cloned().fold(f64::MIN, f64::max);
        assert!((avg - 1.47).abs() < 0.2, "avg speedup {avg} ({speedups:?})");
        assert!((max - 1.70).abs() < 0.3, "max speedup {max}");
        for s in &speedups {
            assert!(*s > 1.0, "gaudi should win everywhere: {s}");
        }
    }

    #[test]
    fn fig12a_multi_device_speedup_grows_with_tp() {
        // Paper: 70B TP speedups 1.29x / 1.32x / 1.35x for 2 / 4 / 8 devices.
        let cfg = LlamaConfig::llama31_70b();
        let mut by_tp = Vec::new();
        for &tp in &[2usize, 4, 8] {
            let mut speedups = Vec::new();
            for (b, o) in fig12_grid() {
                let g = serve_fixed(&cfg, DeviceKind::Gaudi2, b, 100, o, tp);
                let a = serve_fixed(&cfg, DeviceKind::A100, b, 100, o, tp);
                speedups.push(a.total_time() / g.total_time());
            }
            by_tp.push(mean(&speedups));
        }
        assert!((by_tp[0] - 1.29).abs() < 0.15, "tp2 {}", by_tp[0]);
        assert!((by_tp[1] - 1.32).abs() < 0.15, "tp4 {}", by_tp[1]);
        assert!((by_tp[2] - 1.35).abs() < 0.15, "tp8 {}", by_tp[2]);
        assert!(by_tp[2] > by_tp[0], "speedup grows with devices: {by_tp:?}");
    }

    #[test]
    fn fig12b_decode_dominates_long_outputs() {
        let cfg = LlamaConfig::llama31_8b();
        let short = serve_fixed(&cfg, DeviceKind::Gaudi2, 64, 100, 25, 1);
        let long = serve_fixed(&cfg, DeviceKind::Gaudi2, 64, 100, 400, 1);
        assert!(long.decode_time / long.total_time() > 0.9);
        assert!(short.decode_time > short.prefill_time);
        // Longer inputs grow prefill share (right panel of Fig 12(b)).
        let long_in = serve_fixed(&cfg, DeviceKind::Gaudi2, 64, 1600, 100, 1);
        assert!(long_in.prefill_time / long_in.total_time()
            > short.prefill_time / short.total_time());
    }

    #[test]
    fn fig13_energy_efficiency() {
        // Paper: Gaudi-2 energy-efficiency 1.48x (1 dev), rising to ~1.56x
        // at 8 devices; multi-device power ~88% of A100's.
        let cfg8 = LlamaConfig::llama31_8b();
        let mut effs = Vec::new();
        for (b, o) in fig12_grid() {
            let g = serve_fixed(&cfg8, DeviceKind::Gaudi2, b, 100, o, 1);
            let a = serve_fixed(&cfg8, DeviceKind::A100, b, 100, o, 1);
            effs.push(g.tokens_per_joule(b, o) / a.tokens_per_joule(b, o));
        }
        let avg1 = mean(&effs);
        assert!((avg1 - 1.48).abs() < 0.30, "1-dev energy eff {avg1}");

        let cfg70 = LlamaConfig::llama31_70b();
        let mut power_ratio = Vec::new();
        let mut eff8 = Vec::new();
        for (b, o) in fig12_grid() {
            let g = serve_fixed(&cfg70, DeviceKind::Gaudi2, b, 100, o, 8);
            let a = serve_fixed(&cfg70, DeviceKind::A100, b, 100, o, 8);
            power_ratio.push(g.avg_power / a.avg_power);
            eff8.push(g.tokens_per_joule(b, o) / a.tokens_per_joule(b, o));
        }
        let pr = mean(&power_ratio);
        let e8 = mean(&eff8);
        assert!((pr - 0.88).abs() < 0.15, "power ratio {pr}");
        assert!((e8 - 1.56).abs() < 0.35, "8-dev energy eff {e8}");
    }

    #[test]
    fn hbm_sizing_70b_needs_a_device_group() {
        // ~141 GB of BF16 weights: no single Gaudi-2 (96 GB) or A100
        // (80 GB) holds Llama-70B, but a tp>=2 group shards it and tp>=4
        // leaves comfortable KV headroom on both — the tp-sweep claim.
        let cfg70 = LlamaConfig::llama31_70b();
        for kind in [DeviceKind::Gaudi2, DeviceKind::A100] {
            assert!(!hbm_feasible(&cfg70, kind, 1, 4096), "{kind:?} tp1 must be HBM-bound");
            assert_eq!(kv_token_capacity(&cfg70, kind, 1), 0);
            assert!(hbm_feasible(&cfg70, kind, 4, 4096), "{kind:?} tp4 must serve");
            assert!(kv_block_budget(&cfg70, kind, 4, 128) > 1000, "{kind:?} tp4 headroom");
            // Token capacity grows monotonically with group width.
            let caps: Vec<usize> =
                [1, 2, 4, 8].iter().map(|&tp| kv_token_capacity(&cfg70, kind, tp)).collect();
            assert!(caps.windows(2).all(|w| w[0] <= w[1]), "{kind:?}: {caps:?}");
        }
        // 8B fits a single card everywhere (the pre-group regime).
        let cfg8 = LlamaConfig::llama31_8b();
        for kind in [DeviceKind::Gaudi2, DeviceKind::A100] {
            assert!(hbm_feasible(&cfg8, kind, 1, 4096));
        }
    }

    #[test]
    fn tp_reduces_latency() {
        let cfg = LlamaConfig::llama31_70b();
        let t2 = serve_fixed(&cfg, DeviceKind::Gaudi2, 16, 100, 100, 2).total_time();
        let t8 = serve_fixed(&cfg, DeviceKind::Gaudi2, 16, 100, 100, 8).total_time();
        assert!(t8 < t2, "tp8 {t8} tp2 {t2}");
    }

    #[test]
    fn throughput_metric_consistency() {
        let cfg = LlamaConfig::llama31_8b();
        let c = serve_fixed(&cfg, DeviceKind::A100, 8, 100, 50, 1);
        assert!((c.throughput(8, 50) - 400.0 / c.total_time()).abs() < 1e-6);
        assert!(c.energy > 0.0 && c.avg_power > 50.0 && c.avg_power < 600.0);
    }
}
