//! DLRM-DCNv2 serving cost model — the RecSys side of §3.5 (Fig 11),
//! with the two MLPerf-derived configurations of Table 3:
//!
//! * **RM1** (compute-intensive): 10 tables × 5M rows, bottom MLP
//!   512-256-64, top MLP 1024-1024-512-256-1, DCNv2 rank 512 × 3 layers.
//! * **RM2** (memory-intensive): 20 tables × 1M rows, bottom MLP
//!   256-64-64, top MLP 128-64-1, DCNv2 rank 64 × 2 layers.
//!
//! End-to-end RecSys runs in FP32 (paper methodology). Gaudi's deficit
//! here comes from (1) sub-256 B embedding-vector gathers and (2) many
//! small launch-bound MLP layers; its wins at wide vectors / large batches
//! come from the MME GEMM advantage.

use crate::config::DeviceKind;
use crate::ops::embedding::{self, EmbeddingImpl, EmbeddingWork};
use crate::ops::mlp;
use crate::sim::device::Device;
use crate::sim::power::{Activity, PowerModel};
use crate::sim::Dtype;

/// A DLRM model configuration.
#[derive(Debug, Clone)]
pub struct DlrmConfig {
    pub name: &'static str,
    pub tables: usize,
    pub rows_per_table: usize,
    /// Lookups per table per sample.
    pub pooling: usize,
    /// Bottom MLP widths (input dim first).
    pub bottom_mlp: Vec<usize>,
    /// Top MLP widths.
    pub top_mlp: Vec<usize>,
    /// DCNv2 low-rank dimension.
    pub cross_rank: usize,
    pub cross_layers: usize,
}

impl DlrmConfig {
    pub fn rm1() -> Self {
        DlrmConfig {
            name: "RM1",
            tables: 10,
            rows_per_table: 5_000_000,
            pooling: 1,
            bottom_mlp: vec![13, 512, 256, 64],
            top_mlp: vec![1024, 1024, 512, 256, 1],
            cross_rank: 512,
            cross_layers: 3,
        }
    }

    pub fn rm2() -> Self {
        DlrmConfig {
            name: "RM2",
            tables: 20,
            rows_per_table: 1_000_000,
            pooling: 20,
            bottom_mlp: vec![13, 256, 64, 64],
            top_mlp: vec![128, 64, 1],
            cross_rank: 64,
            cross_layers: 2,
        }
    }

    /// Feature dimension entering the interaction layer, given the
    /// embedding dimension in elements.
    fn interaction_dim(&self, emb_dim: usize) -> usize {
        // Concatenated pooled embeddings + dense bottom output.
        self.tables * emb_dim + *self.bottom_mlp.last().unwrap()
    }
}

/// Cost of serving one batch through a DLRM model.
#[derive(Debug, Clone, Copy)]
pub struct DlrmCost {
    pub time: f64,
    pub embedding_time: f64,
    pub dense_time: f64,
    pub energy: f64,
    pub avg_power: f64,
}

impl DlrmCost {
    pub fn throughput(&self, batch: usize) -> f64 {
        batch as f64 / self.time
    }

    pub fn samples_per_joule(&self, batch: usize) -> f64 {
        batch as f64 / self.energy
    }
}

/// Serve one batch. `emb_dim` is the embedding vector dimension in
/// elements (FP32 ⇒ vector bytes = 4 × emb_dim).
pub fn serve(cfg: &DlrmConfig, kind: DeviceKind, batch: usize, emb_dim: usize) -> DlrmCost {
    let dev = Device::new(kind);
    let dtype = Dtype::Fp32;
    let vec_bytes = emb_dim as f64 * dtype.bytes();

    // Embedding layer: best-available operator per device (the paper's
    // end-to-end Gaudi numbers use their custom BatchedTable).
    let emb_impl = match kind {
        DeviceKind::Gaudi2 => EmbeddingImpl::GaudiBatchedTable,
        DeviceKind::A100 => EmbeddingImpl::A100Fbgemm,
    };
    let work = EmbeddingWork { tables: cfg.tables, batch, pooling: cfg.pooling, vec_bytes };
    let emb = embedding::run(emb_impl, work, dtype);

    // Dense side: bottom MLP → DCNv2 interaction → top MLP.
    let bottom = mlp::mlp(&dev, batch, &cfg.bottom_mlp, dtype);
    let inter_dim = cfg.interaction_dim(emb_dim);
    let cross = mlp::dcn_interaction(&dev, batch, inter_dim, cfg.cross_rank, cfg.cross_layers);
    // Top MLP input is the interaction output; prepend its true width.
    let mut top_widths = vec![inter_dim];
    top_widths.extend_from_slice(&cfg.top_mlp[1..]);
    let top = mlp::mlp(&dev, batch, &top_widths, dtype);

    let dense_time = bottom.time + cross.time + top.time;
    let time = emb.time + dense_time;

    // Power: embedding phase is HBM-dominated; dense phase exercises the
    // matrix engine at the measured per-layer utilization.
    let power = PowerModel::for_device(kind);
    let emb_power = power.power(Activity {
        matrix_util: 0.0,
        matrix_active_fraction: 0.0,
        vector_util: 0.5,
        hbm_util: emb.bandwidth_utilization / 0.745,
        comm_util: 0.0,
    });
    let n_dense = 3.0;
    let dense_util = (bottom.avg_matrix_util + cross.avg_matrix_util + top.avg_matrix_util) / n_dense;
    let dense_active = match kind {
        DeviceKind::Gaudi2 => {
            (bottom.avg_active_fraction + cross.avg_active_fraction + top.avg_active_fraction)
                / n_dense
        }
        DeviceKind::A100 => 1.0,
    };
    let dense_power = power.power(Activity {
        matrix_util: dense_util,
        matrix_active_fraction: dense_active,
        vector_util: 0.3,
        hbm_util: 0.4,
        comm_util: 0.0,
    });
    let energy = emb.time * emb_power + dense_time * dense_power;
    DlrmCost {
        time,
        embedding_time: emb.time,
        dense_time,
        energy,
        avg_power: energy / time,
    }
}

/// The Fig 11 sweep grid: batch × embedding dim (elements, FP32).
pub fn fig11_grid() -> Vec<(usize, usize)> {
    let mut v = Vec::new();
    for &batch in &[256usize, 1024, 4096, 16384] {
        for &dim in &[32usize, 64, 128, 256, 512] {
            v.push((batch, dim));
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    fn speedups(cfg: &DlrmConfig) -> Vec<f64> {
        fig11_grid()
            .into_iter()
            .map(|(b, d)| {
                serve(cfg, DeviceKind::A100, b, d).time / serve(cfg, DeviceKind::Gaudi2, b, d).time
            })
            .collect()
    }

    #[test]
    fn fig11_rm1_gaudi_loses_about_22pct() {
        let s = speedups(&DlrmConfig::rm1());
        let avg = mean(&s);
        // Paper: average performance degradation of 22% (speedup ~0.78).
        assert!((avg - 0.78).abs() < 0.12, "rm1 avg speedup {avg} ({s:?})");
    }

    #[test]
    fn fig11_rm2_gaudi_loses_about_18pct() {
        let s = speedups(&DlrmConfig::rm2());
        let avg = mean(&s);
        assert!((avg - 0.82).abs() < 0.12, "rm2 avg speedup {avg} ({s:?})");
    }

    #[test]
    fn fig11_gaudi_wins_wide_vectors_large_batch() {
        // Paper: maximum 1.36x speedup at wide vectors + large batch.
        let cfg = DlrmConfig::rm1();
        let wide =
            serve(&cfg, DeviceKind::A100, 16384, 256).time / serve(&cfg, DeviceKind::Gaudi2, 16384, 256).time;
        assert!(wide > 1.0, "gaudi should win at wide/large: {wide}");
        assert!(wide < 1.7, "but not by more than the paper's band: {wide}");
    }

    #[test]
    fn fig11_rm2_small_vectors_big_loss() {
        // Paper: up to 70% performance loss for <256 B vectors in RM2.
        let cfg = DlrmConfig::rm2();
        let worst = fig11_grid()
            .into_iter()
            .filter(|&(_, d)| d * 4 < 256)
            .map(|(b, d)| {
                serve(&cfg, DeviceKind::A100, b, d).time / serve(&cfg, DeviceKind::Gaudi2, b, d).time
            })
            .fold(f64::MAX, f64::min);
        assert!(worst < 0.55, "worst small-vector speedup {worst}");
        assert!(worst > 0.20, "not catastrophically below the paper: {worst}");
    }

    #[test]
    fn fig11_energy_gaudi_28pct_worse() {
        // Paper: Gaudi-2's energy consumption ~28% higher on average
        // (RM1+RM2), i.e. samples/J ratio ~0.78, with ~12% higher power.
        let mut eff = Vec::new();
        let mut pwr = Vec::new();
        for cfg in [DlrmConfig::rm1(), DlrmConfig::rm2()] {
            for (b, d) in fig11_grid() {
                let g = serve(&cfg, DeviceKind::Gaudi2, b, d);
                let a = serve(&cfg, DeviceKind::A100, b, d);
                eff.push(g.samples_per_joule(b) / a.samples_per_joule(b));
                pwr.push(g.avg_power / a.avg_power);
            }
        }
        let avg_eff = mean(&eff);
        let avg_pwr = mean(&pwr);
        assert!((avg_eff - 0.78).abs() < 0.15, "energy-eff ratio {avg_eff}");
        assert!((avg_pwr - 1.12).abs() < 0.15, "power ratio {avg_pwr}");
    }

    #[test]
    fn rm2_is_embedding_dominated_rm1_is_dense_dominated() {
        let rm1 = serve(&DlrmConfig::rm1(), DeviceKind::A100, 4096, 128);
        let rm2 = serve(&DlrmConfig::rm2(), DeviceKind::A100, 4096, 128);
        assert!(
            rm2.embedding_time / rm2.time > rm1.embedding_time / rm1.time,
            "rm2 emb share {} rm1 {}",
            rm2.embedding_time / rm2.time,
            rm1.embedding_time / rm1.time
        );
        assert!(rm1.dense_time > rm1.embedding_time, "rm1 dense-dominated");
    }

    #[test]
    fn cost_metrics_consistent() {
        let c = serve(&DlrmConfig::rm1(), DeviceKind::Gaudi2, 1024, 128);
        assert!(c.time > 0.0 && c.energy > 0.0);
        assert!((c.throughput(1024) - 1024.0 / c.time).abs() < 1e-6);
        assert!(c.avg_power > 100.0 && c.avg_power < 600.0, "power {}", c.avg_power);
    }
}
