//! End-to-end workload models with the paper's exact configurations
//! (Table 3): DLRM-DCNv2 (RM1/RM2) and Llama-3.1 (8B/70B).

pub mod dlrm;
pub mod dlrm_multi;
pub mod llama;
pub mod llama_training;
