//! Llama training-step cost model — the paper's stated immediate future
//! work ("Analyzing Gaudi's competitive edge against NVIDIA GPUs in
//! training scenarios is part of our immediate future work").
//!
//! Model: synchronous data-parallel (optionally tensor-parallel) training.
//! Per step: forward = prefill-style GEMMs over the tokens, backward ≈ 2×
//! forward FLOPs, plus a gradient AllReduce of the full parameter set
//! across data-parallel peers (overlapped with backward up to the
//! bandwidth bound). Training is compute-bound at realistic batch sizes,
//! so Gaudi's GEMM advantage carries over — but the P2P mesh taxes the
//! gradient AllReduce at small device counts, mirroring Fig 10.

use crate::config::DeviceKind;
use crate::models::llama::LlamaConfig;
use crate::sim::collective;
use crate::sim::device::Device;
use crate::sim::graph_compiler;
use crate::sim::Dtype;

/// One training step's cost.
#[derive(Debug, Clone, Copy)]
pub struct TrainStepCost {
    pub compute_time: f64,
    pub allreduce_time: f64,
    /// Wall time with compute/communication overlap.
    pub step_time: f64,
    /// Tokens processed per second per device.
    pub tokens_per_sec_per_device: f64,
}

/// Cost of one synchronous training step.
///
/// * `per_device_batch` sequences of `seq_len` tokens per device;
/// * `dp` data-parallel replicas within the 8-device node (gradients
///   all-reduced across them).
pub fn train_step(
    cfg: &LlamaConfig,
    kind: DeviceKind,
    per_device_batch: usize,
    seq_len: usize,
    dp: usize,
) -> TrainStepCost {
    assert!((1..=8).contains(&dp));
    let dev = Device::new(kind);
    let tokens = per_device_batch * seq_len;
    let h = cfg.hidden;
    let q = cfg.n_q_heads * cfg.head_dim;
    let kv = cfg.n_kv_heads * cfg.head_dim;

    // Forward GEMM time per layer (same shapes as serving prefill).
    let fwd_layer = dev.gemm(tokens, h, q + 2 * kv, Dtype::Bf16).time
        + dev.gemm(tokens, q, h, Dtype::Bf16).time
        + dev.gemm(tokens, h, 2 * cfg.intermediate, Dtype::Bf16).time
        + dev.gemm(tokens, cfg.intermediate, h, Dtype::Bf16).time
        + crate::ops::attention::prefill_attention_time(
            &dev,
            per_device_batch,
            seq_len,
            cfg.n_q_heads,
            cfg.head_dim,
        );
    // Backward: dgrad + wgrad ≈ 2× forward GEMM work.
    let compute = cfg.layers as f64 * fwd_layer * 3.0
        + dev.gemm(per_device_batch, h, cfg.vocab, Dtype::Bf16).time * 3.0;

    // Gradient AllReduce of all parameters (BF16 grads).
    let allreduce = if dp > 1 {
        collective::allreduce_time(kind, dp, cfg.weight_bytes())
    } else {
        0.0
    };
    // Backward/communication overlap: the graph compiler (or NCCL stream)
    // pipelines per-layer gradient buckets behind remaining backward work.
    let overlapped = graph_compiler::pipeline2(
        &dev.spec,
        compute * 2.0 / 3.0, // backward portion
        allreduce,
        cfg.weight_bytes(),
        true,
    );
    let step_time = compute / 3.0 + overlapped.time;
    TrainStepCost {
        compute_time: compute,
        allreduce_time: allreduce,
        step_time,
        tokens_per_sec_per_device: tokens as f64 / step_time,
    }
}

/// Gaudi-2 / A100 training-throughput ratio at a configuration.
pub fn speedup(cfg: &LlamaConfig, per_device_batch: usize, seq_len: usize, dp: usize) -> f64 {
    let g = train_step(cfg, DeviceKind::Gaudi2, per_device_batch, seq_len, dp);
    let a = train_step(cfg, DeviceKind::A100, per_device_batch, seq_len, dp);
    g.tokens_per_sec_per_device / a.tokens_per_sec_per_device
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn training_is_compute_bound_and_gaudi_wins() {
        // Paper conjecture: Gaudi's GEMM advantage should carry to
        // training. At realistic batch (8 x 4096 tokens) the step is
        // compute-bound and the speedup tracks the MME advantage (~1.4-1.7).
        let cfg = LlamaConfig::llama31_8b();
        let s = speedup(&cfg, 8, 4096, 8);
        assert!(s > 1.2 && s < 1.8, "training speedup {s}");
        let c = train_step(&cfg, DeviceKind::Gaudi2, 8, 4096, 8);
        assert!(c.compute_time > 2.0 * c.allreduce_time, "compute-bound");
    }

    #[test]
    fn gradient_allreduce_hurts_small_dp_on_gaudi() {
        // At dp=2 the Gaudi mesh gives 1/7 of its fabric: its advantage
        // shrinks relative to dp=8 (the paper's Fig-10 mechanism).
        let cfg = LlamaConfig::llama31_8b();
        let s2 = speedup(&cfg, 2, 1024, 2);
        let s8 = speedup(&cfg, 2, 1024, 8);
        assert!(s8 > s2, "dp8 {s8} should beat dp2 {s2}");
    }

    #[test]
    fn backward_is_twice_forward() {
        let cfg = LlamaConfig::llama31_8b();
        let c = train_step(&cfg, DeviceKind::A100, 4, 2048, 1);
        assert_eq!(c.allreduce_time, 0.0);
        assert!(c.step_time <= c.compute_time + 1e-12);
        assert!(c.tokens_per_sec_per_device > 0.0);
    }

    #[test]
    fn overlap_hides_communication_at_scale() {
        let cfg = LlamaConfig::llama31_70b();
        let c = train_step(&cfg, DeviceKind::Gaudi2, 2, 4096, 8);
        // Step time is well below compute + allreduce (overlap works).
        assert!(c.step_time < c.compute_time + 0.9 * c.allreduce_time);
    }
}
