//! Multi-device RecSys serving — the capability the paper notes the Gaudi
//! SDK *lacks* ("Intel Gaudi SDK currently lacks support for multi-device
//! RecSys serving, a feature natively supported in TorchRec"). We build it
//! for both devices, TorchRec-style:
//!
//! * embedding tables are **model-parallel** (sharded by table across
//!   devices) — each device gathers its local shard for the *global*
//!   batch, then an **AllToAll** redistributes pooled embeddings to the
//!   batch-parallel layout;
//! * dense layers are **data-parallel** (batch sharded), no communication
//!   at inference.
//!
//! The interesting emergent result: A100 scales smoothly (NVSwitch
//! AllToAll), while Gaudi's P2P mesh makes small device counts
//! communication-bound — the same mechanism as Fig 10 applied to the
//! workload the paper could not run.

use crate::config::DeviceKind;
use crate::models::dlrm::{serve, DlrmConfig};
use crate::ops::embedding::{self, EmbeddingImpl, EmbeddingWork};
use crate::sim::collective::{self, Collective};
use crate::sim::device::Device;
use crate::sim::Dtype;
use crate::util::ceil_div;

/// Cost of serving one *global* batch over `n_devices`.
#[derive(Debug, Clone, Copy)]
pub struct MultiDlrmCost {
    pub time: f64,
    pub embedding_time: f64,
    pub alltoall_time: f64,
    pub dense_time: f64,
}

impl MultiDlrmCost {
    pub fn throughput(&self, global_batch: usize) -> f64 {
        global_batch as f64 / self.time
    }
}

/// Serve one global batch with table-sharded embeddings + AllToAll +
/// data-parallel dense.
pub fn serve_multi(
    cfg: &DlrmConfig,
    kind: DeviceKind,
    global_batch: usize,
    emb_dim: usize,
    n_devices: usize,
) -> MultiDlrmCost {
    assert!(n_devices >= 1 && n_devices <= 8);
    if n_devices == 1 {
        let c = serve(cfg, kind, global_batch, emb_dim);
        return MultiDlrmCost {
            time: c.time,
            embedding_time: c.embedding_time,
            alltoall_time: 0.0,
            dense_time: c.dense_time,
        };
    }
    let dev = Device::new(kind);
    let dtype = Dtype::Fp32;
    let vec_bytes = emb_dim as f64 * dtype.bytes();
    // Each device owns ceil(tables/n) tables and gathers them for the FULL
    // global batch (model parallelism).
    let local_tables = ceil_div(cfg.tables, n_devices);
    let emb_impl = match kind {
        DeviceKind::Gaudi2 => EmbeddingImpl::GaudiBatchedTable,
        DeviceKind::A100 => EmbeddingImpl::A100Fbgemm,
    };
    let work = EmbeddingWork {
        tables: local_tables,
        batch: global_batch,
        pooling: cfg.pooling,
        vec_bytes,
    };
    let emb = embedding::run(emb_impl, work, dtype);

    // AllToAll: each device holds [global_batch × local_tables × dim] and
    // needs [local_batch × all_tables × dim].
    let payload = global_batch as f64 * local_tables as f64 * vec_bytes;
    let a2a = collective::run(kind, Collective::AllToAll, n_devices, payload).time;

    // Dense side runs data-parallel on the local batch shard.
    let local_batch = ceil_div(global_batch, n_devices);
    let dense = {
        let c = serve(cfg, kind, local_batch, emb_dim);
        c.dense_time
    };
    let _ = dev;
    MultiDlrmCost {
        time: emb.time + a2a + dense,
        embedding_time: emb.time,
        alltoall_time: a2a,
        dense_time: dense,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_helps_both_devices_at_8() {
        let cfg = DlrmConfig::rm2();
        for kind in [DeviceKind::Gaudi2, DeviceKind::A100] {
            let t1 = serve_multi(&cfg, kind, 65536, 128, 1).time;
            let t8 = serve_multi(&cfg, kind, 65536, 128, 8).time;
            assert!(t8 < t1, "{kind:?}: t1 {t1} t8 {t8}");
        }
    }

    #[test]
    fn gaudi_scaling_hurt_by_p2p_alltoall_at_2_devices() {
        // The Fig-10 mechanism applied to RecSys: at 2 devices Gaudi's
        // AllToAll runs over a single 37.5 GB/s pair, so its parallel
        // efficiency trails A100's.
        let cfg = DlrmConfig::rm2();
        let eff = |kind| {
            let t1 = serve_multi(&cfg, kind, 65536, 128, 1).time;
            let t2 = serve_multi(&cfg, kind, 65536, 128, 2).time;
            t1 / (2.0 * t2) // parallel efficiency
        };
        let g = eff(DeviceKind::Gaudi2);
        let a = eff(DeviceKind::A100);
        assert!(a > g, "a100 eff {a} should beat gaudi {g}");
    }

    #[test]
    fn alltoall_share_shrinks_with_devices_on_gaudi() {
        let cfg = DlrmConfig::rm2();
        let share = |n| {
            let c = serve_multi(&cfg, DeviceKind::Gaudi2, 65536, 128, n);
            c.alltoall_time / c.time
        };
        assert!(share(2) > share(8), "2dev {} vs 8dev {}", share(2), share(8));
    }

    #[test]
    fn single_device_matches_base_model() {
        let cfg = DlrmConfig::rm1();
        let multi = serve_multi(&cfg, DeviceKind::A100, 4096, 128, 1);
        let single = serve(&cfg, DeviceKind::A100, 4096, 128);
        assert!((multi.time - single.time).abs() < 1e-12);
        assert_eq!(multi.alltoall_time, 0.0);
    }
}
