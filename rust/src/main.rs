//! `repro` — the leader entrypoint / CLI.
//!
//! ```text
//! repro list                       # show every reproducible table/figure
//! repro run <exp|all> [--csv] [--json] [--out DIR] [--check]
//!           [--param k=v ...] [--jobs N]
//!                                  # regenerate a paper table/figure;
//!                                  # --json prints one artifact per
//!                                  # experiment, --out DIR writes them as
//!                                  # BENCH_<id>.json, --check evaluates
//!                                  # the paper-claim expectations and
//!                                  # exits non-zero on any failure;
//!                                  # --param overrides a declared
//!                                  # experiment parameter (repeatable);
//!                                  # --jobs N fans experiments and sweep
//!                                  # grid points across N workers
//!                                  # (default: all cores) — artifacts
//!                                  # are byte-identical at any N
//! repro bench-diff <baseline-dir> <candidate-dir> [--tolerance PCT]
//!                                  # compare two BENCH_*.json artifact
//!                                  # directories cell-by-cell; prints the
//!                                  # typed delta table and exits non-zero
//!                                  # on regressions beyond tolerance
//! repro serve [--config f.json] [--requests N] [--rate R] [--json]
//!                                  # run the vLLM-style serving cluster
//!                                  # (1..N replicas, homogeneous or a
//!                                  # mixed Gaudi-2/A100 fleet, simulated
//!                                  # backend) on a Dynamic-Sonnet load;
//!                                  # configs with `"classes": [...]`
//!                                  # serve a mixed-class trace and
//!                                  # report per-class attainment
//! repro real-serve [--artifacts d] [--requests N]
//!                                  # serve the REAL tiny-Llama artifacts
//!                                  # through PJRT (needs `make artifacts`)
//! ```
//!
//! Malformed flag values and unrecognized flags are usage errors
//! (exit 2), never silent fallbacks to defaults.

use cuda_myth::config::ServingConfig;
use cuda_myth::harness::{self, Experiment};
use cuda_myth::models::llama::LlamaConfig;
use cuda_myth::report::diff::{self, DiffOutcome};
use cuda_myth::report::expect::results_report;
use cuda_myth::serving::chaos::FaultSchedule;
use cuda_myth::serving::cluster::ClusterSim;
use cuda_myth::serving::real_engine::PjrtLlmEngine;
use cuda_myth::serving::router::RoutePolicy;
use cuda_myth::util::json::Json;
use cuda_myth::util::par;
use cuda_myth::workload::{DynamicSonnet, TokenPrompts};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("list") => cmd_list(),
        Some("run") => cmd_run(&args[1..]),
        Some("bench-diff") => cmd_bench_diff(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("real-serve") => cmd_real_serve(&args[1..]),
        _ => {
            eprintln!(
                "usage: repro <list|run <exp|all> [--csv] [--json] [--out DIR] [--check] \
                 [--param k=v] [--jobs N]|bench-diff <base> <cand> [--tolerance PCT]\
                 |serve [opts]|real-serve [opts]>"
            );
            2
        }
    };
    std::process::exit(code);
}

fn cmd_list() -> i32 {
    println!("experiments (repro run <id>):");
    for e in harness::registry() {
        println!("  {:16} {}", e.id(), e.title());
    }
    0
}

/// `--name <value>`: Ok(None) if absent, Err if the value is missing —
/// including when the next token is another `--flag` (a forgotten value
/// must not silently swallow the following flag).
fn flag_value<'a>(args: &'a [String], name: &str) -> Result<Option<&'a str>, String> {
    match args.iter().position(|a| a == name) {
        None => Ok(None),
        Some(i) => match args.get(i + 1) {
            Some(v) if !v.starts_with("--") => Ok(Some(v.as_str())),
            _ => Err(format!("missing value for {name}")),
        },
    }
}

/// Typed flag with a default; a present-but-malformed value is an error,
/// never a silent fallback.
fn parse_flag<T: std::str::FromStr>(args: &[String], name: &str, default: T) -> Result<T, String> {
    match flag_value(args, name)? {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| format!("invalid value '{v}' for {name}")),
    }
}

/// Every occurrence of a repeatable `--name <value>` flag, in order.
fn flag_values<'a>(args: &'a [String], name: &str) -> Result<Vec<&'a str>, String> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == name {
            match args.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    out.push(v.as_str());
                    i += 2;
                    continue;
                }
                _ => return Err(format!("missing value for {name}")),
            }
        }
        i += 1;
    }
    Ok(out)
}

/// Parse repeated `--param k=v` overrides into typed pairs.
fn parse_param_overrides(raw: &[&str]) -> Result<Vec<(String, f64)>, String> {
    raw.iter()
        .map(|s| {
            let (k, v) = s
                .split_once('=')
                .ok_or_else(|| format!("invalid --param '{s}' (want key=value)"))?;
            if k.is_empty() {
                return Err(format!("invalid --param '{s}' (empty key)"));
            }
            let x: f64 = v
                .parse()
                .map_err(|_| format!("invalid --param value '{v}' for '{k}' (want a number)"))?;
            Ok((k.to_string(), x))
        })
        .collect()
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

/// Reject unrecognized `--flags`: a typo'd `--chek` must be a usage
/// error, not a silently skipped check.
fn reject_unknown_flags(args: &[String], known: &[&str]) -> Result<(), String> {
    match args.iter().find(|a| a.starts_with("--") && !known.contains(&a.as_str())) {
        Some(a) => Err(format!("unknown flag '{a}'")),
        None => Ok(()),
    }
}

fn cmd_run(args: &[String]) -> i32 {
    const USAGE: &str = "usage: repro run <exp|all> [--csv] [--json] [--out DIR] [--check] \
                         [--param k=v ...] [--jobs N]";
    let Some(id) = args.first() else {
        eprintln!("{USAGE}");
        return 2;
    };
    if let Err(e) = reject_unknown_flags(
        args,
        &["--csv", "--json", "--out", "--check", "--param", "--jobs"],
    ) {
        eprintln!("{e}\n{USAGE}");
        return 2;
    }
    let csv = has_flag(args, "--csv");
    let json = has_flag(args, "--json");
    let check = has_flag(args, "--check");
    let jobs = match parse_flag::<usize>(args, "--jobs", par::available_jobs()) {
        Ok(j) if j >= 1 => j,
        Ok(j) => {
            eprintln!("--jobs must be >= 1, got {j}\n{USAGE}");
            return 2;
        }
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            return 2;
        }
    };
    par::configure_jobs(jobs);
    let out_dir = match flag_value(args, "--out") {
        Ok(d) => d.map(str::to_string),
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            return 2;
        }
    };
    let overrides = match flag_values(args, "--param").and_then(|raw| parse_param_overrides(&raw))
    {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            return 2;
        }
    };
    if csv && (json || out_dir.is_some()) {
        eprintln!("--csv cannot be combined with --json/--out\n{USAGE}");
        return 2;
    }

    let exps: Vec<Box<dyn Experiment>> = if id == "all" {
        harness::registry()
    } else {
        match harness::find(id) {
            Some(e) => vec![e],
            None => {
                eprintln!("unknown experiment '{id}' (see `repro list`)");
                return 2;
            }
        }
    };

    // An override must name a parameter some selected experiment declares
    // — a typo'd key must be a usage error, not a silent no-op sweep.
    for (k, _) in &overrides {
        if !exps.iter().any(|e| e.params().get(k).is_some()) {
            eprintln!(
                "--param '{k}' matches no declared parameter of the selected experiment(s)\n\
                 {USAGE}"
            );
            return 2;
        }
    }

    if let Some(dir) = &out_dir {
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("cannot create --out directory '{dir}': {e}");
            return 1;
        }
    }

    let emit_artifacts = json || out_dir.is_some();
    // Fan the selected experiments across the worker pool; results come
    // back in registry order at any --jobs value, so artifact emission
    // below is deterministic and byte-identical (the jobs-invariance
    // contract). A panicking experiment fails alone — its siblings'
    // artifacts still land.
    let runs = harness::run_all_isolated(&exps, &overrides);
    let mut panicked = false;
    let mut all_results = Vec::new();
    for run in &runs {
        if let Some(msg) = &run.panic {
            eprintln!("experiment '{}' panicked: {msg}", run.id);
            panicked = true;
        } else if emit_artifacts {
            let e = harness::find(run.id).expect("run ids come from the registry");
            let artifact =
                harness::artifact_json(e.as_ref(), &run.params, &run.reports, &run.results);
            match &out_dir {
                Some(dir) => {
                    let path = format!("{dir}/BENCH_{}.json", run.id);
                    if let Err(err) = std::fs::write(&path, artifact.dump()) {
                        eprintln!("cannot write '{path}': {err}");
                        return 1;
                    }
                    println!("wrote {path}");
                }
                None => println!("{}", artifact.dump()),
            }
        } else {
            for r in &run.reports {
                if csv {
                    println!("# {}", r.title());
                    print!("{}", r.to_csv());
                } else {
                    r.print();
                }
            }
        }
        all_results.extend(run.results.iter().cloned());
    }

    // `run all` also reports what each experiment cost: the one
    // deliberately jobs-/machine-dependent table, shipped in its own
    // BENCH_run_wall.json so the per-experiment artifacts stay
    // byte-identical across --jobs.
    if id == "all" {
        let wall = harness::wall_report(&runs, jobs).render();
        if emit_artifacts {
            eprintln!("{wall}");
        } else {
            println!("{wall}");
        }
        if let Some(dir) = &out_dir {
            let path = format!("{dir}/BENCH_run_wall.json");
            if let Err(err) =
                std::fs::write(&path, harness::wall_artifact_json(&runs, jobs).dump())
            {
                eprintln!("cannot write '{path}': {err}");
                return 1;
            }
            println!("wrote {path}");
        }
    }

    if check {
        // In --json mode stdout is a pure NDJSON artifact stream; the
        // human-readable PASS/FAIL table goes to stderr.
        let table = results_report(&all_results).render();
        if emit_artifacts {
            eprintln!("{table}");
        } else {
            println!("{table}");
        }
        if all_results.iter().any(|r| !r.pass) {
            return 1;
        }
    }
    if panicked {
        return 1;
    }
    0
}

/// Sorted `BENCH_*.json` file names in `dir`.
fn bench_artifact_files(dir: &str) -> Result<Vec<String>, String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("cannot read '{dir}': {e}"))?;
    let mut names: Vec<String> = entries
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        .collect();
    names.sort();
    Ok(names)
}

fn load_artifact(dir: &str, name: &str) -> Result<Json, String> {
    let path = format!("{dir}/{name}");
    let text = std::fs::read_to_string(&path).map_err(|e| format!("cannot read '{path}': {e}"))?;
    Json::parse(&text).map_err(|e| format!("'{path}': {e}"))
}

fn cmd_bench_diff(args: &[String]) -> i32 {
    const USAGE: &str = "usage: repro bench-diff <baseline-dir> <candidate-dir> [--tolerance PCT]";
    if let Err(e) = reject_unknown_flags(args, &["--tolerance"]) {
        eprintln!("{e}\n{USAGE}");
        return 2;
    }
    let positional: Vec<&String> = {
        // Everything that is neither a flag nor a flag's value.
        let mut out = Vec::new();
        let mut i = 0;
        while i < args.len() {
            if args[i].starts_with("--") {
                i += 2; // flag + value
                continue;
            }
            out.push(&args[i]);
            i += 1;
        }
        out
    };
    let [baseline, candidate] = positional.as_slice() else {
        eprintln!("{USAGE}");
        return 2;
    };
    let tolerance = match parse_flag::<f64>(args, "--tolerance", 1.0) {
        Ok(t) if t >= 0.0 => t,
        Ok(t) => {
            eprintln!("--tolerance must be >= 0, got {t}\n{USAGE}");
            return 2;
        }
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            return 2;
        }
    };

    let (base_files, cand_files) = match (
        bench_artifact_files(baseline),
        bench_artifact_files(candidate),
    ) {
        (Ok(b), Ok(c)) => (b, c),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if base_files.is_empty() {
        eprintln!("no BENCH_*.json artifacts in baseline '{baseline}'");
        return 2;
    }

    let mut outcome = DiffOutcome::default();
    for name in &base_files {
        if !cand_files.contains(name) {
            outcome.structural.push(format!("artifact {name} missing from candidate"));
            continue;
        }
        let pair = load_artifact(baseline, name)
            .and_then(|b| load_artifact(candidate, name).map(|c| (b, c)))
            .and_then(|(b, c)| diff::diff_artifacts(&b, &c, tolerance));
        match pair {
            Ok(one) => outcome.merge(one),
            Err(e) => {
                eprintln!("diff failed for {name}: {e}");
                return 2;
            }
        }
    }
    for name in &cand_files {
        if !base_files.contains(name) {
            outcome.additions.push(format!("new artifact {name}"));
        }
    }

    outcome.to_report(tolerance).print();
    if outcome.has_regressions() {
        eprintln!(
            "bench-diff: {} regression(s) beyond +-{tolerance}% (baseline '{baseline}', \
             candidate '{candidate}')",
            outcome.regressions()
        );
        return 1;
    }
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    const USAGE: &str = "usage: repro serve [--config f.json] [--requests N] [--rate R] \
                         [--chaos faults.json] [--json]";
    if let Err(e) =
        reject_unknown_flags(args, &["--config", "--requests", "--rate", "--chaos", "--json"])
    {
        eprintln!("{e}\n{USAGE}");
        return 2;
    }
    let cfg = match flag_value(args, "--config") {
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            return 2;
        }
        Ok(Some(path)) => match std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("{e}"))
            .and_then(|s| ServingConfig::from_json(&s))
        {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        },
        Ok(None) => ServingConfig { num_blocks: 8192, ..Default::default() },
    };
    let (n, rate) = match (
        parse_flag::<usize>(args, "--requests", 64),
        parse_flag::<f64>(args, "--rate", f64::INFINITY),
    ) {
        (Ok(n), Ok(rate)) => (n, rate),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("{e}\n{USAGE}");
            return 2;
        }
    };
    // Optional fault schedule (`serving::chaos`): a JSON list of seeded
    // crash / straggler / preemption-storm events injected into the run.
    let chaos = match flag_value(args, "--chaos") {
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            return 2;
        }
        Ok(Some(path)) => match std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("{e}"))
            .and_then(|s| FaultSchedule::from_json(&s))
            .and_then(|sched| sched.validate(cfg.replicas).map(|()| sched))
        {
            Ok(sched) => Some(sched),
            Err(e) => {
                eprintln!("chaos schedule error: {e}");
                return 2;
            }
        },
        Ok(None) => None,
    };
    let as_json = has_flag(args, "--json");
    if !as_json {
        println!("serving config: {}", cfg.to_json());
    }

    // One path for every fleet size: a 1-replica cluster is
    // integration-tested bitwise-equal to the bare engine. Heterogeneous
    // fleets (`"fleet": ["gaudi2", "a100", ...]` in --config) run the
    // same path with per-replica devices, and each entry may instead be a
    // device group (`{"device": "gaudi2", "tp": 4}`) whose cards shard
    // the model tensor-parallel behind one replica slot.
    let replicas = cfg.replicas;
    let fleet_desc =
        cfg.replica_specs().iter().map(|s| s.desc()).collect::<Vec<_>>().join("+");
    let policy = cfg.route_policy;
    // Prefix-affinity routing needs prefix-tagged requests to have any
    // warm cache to exploit; tagging is RNG-free, so the other policies'
    // traces are byte-identical with or without it.
    let workload = if policy == RoutePolicy::PrefixAffinity {
        DynamicSonnet::default().with_prefix_groups(8)
    } else {
        DynamicSonnet::default()
    };
    // Multi-class configs (`"classes": [...]`): spread the trace across
    // the declared classes in equal shares. Class tagging is RNG-free
    // too, so single-class runs are byte-identical to the legacy trace.
    let workload = if cfg.classes.len() > 1 {
        workload.with_class_mix((0..cfg.classes.len()).map(|c| (c, 1)).collect())
    } else {
        workload
    };
    let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
    if let Some(sched) = &chaos {
        sim.install_chaos(sched);
    }
    sim.submit_all(workload.generate(n, rate, 7));
    let s = sim.run_to_completion();
    let cache = sim.fleet_prefix_stats();
    if as_json {
        // Pure-JSON stdout (pipe-friendly, like `repro run --json`).
        let mut j = s.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("replicas".into(), Json::Num(replicas as f64));
            m.insert("route_policy".into(), Json::Str(policy.name().into()));
            m.insert("requeues".into(), Json::Num(sim.requeues as f64));
            if chaos.is_some() {
                let st = sim.chaos_stats();
                m.insert("chaos_crashes".into(), Json::Num(st.crashes as f64));
                m.insert("chaos_restarts".into(), Json::Num(st.restarts as f64));
                m.insert("chaos_requeued".into(), Json::Num(st.requeued_by_crash as f64));
                m.insert("chaos_hedges".into(), Json::Num(st.hedges_launched as f64));
                m.insert("chaos_shed".into(), Json::Num(st.shed as f64));
            }
            m.insert("prefix_cache_hit_rate".into(), Json::Num(cache.hit_rate()));
            m.insert(
                "prefix_cache_evictions".into(),
                Json::Num(cache.evictions as f64),
            );
        }
        println!("{}", j.dump());
        return 0;
    }
    println!(
        "served {} requests over {} replica(s) [{fleet_desc}] ({}): {:.1} tok/s, \
         mean TTFT {:.1} ms, p99 TTFT {:.1} ms, mean TPOT {:.2} ms, \
         {:.0} J ({:.3} J/tok), prefix cache {:.0}% hit ({} evictions), \
         {} backpressure requeues",
        s.requests,
        replicas,
        policy.name(),
        s.throughput_tps,
        s.mean_ttft * 1e3,
        s.p99_ttft * 1e3,
        s.mean_tpot * 1e3,
        s.energy_j,
        s.joule_per_tok,
        cache.hit_rate() * 100.0,
        cache.evictions,
        sim.requeues,
    );
    if chaos.is_some() {
        let st = sim.chaos_stats();
        println!(
            "  chaos: {} crash(es) ({} skipped), {} restart(s), {} requeued, \
             {} straggler window(s), {} storm(s), {} hedge(s) launched ({} won), {} shed",
            st.crashes,
            st.crashes_skipped,
            st.restarts,
            st.requeued_by_crash,
            st.straggler_windows,
            st.storms,
            st.hedges_launched,
            st.hedges_won,
            st.shed,
        );
    }
    // Per-traffic-class breakdown (one line per declared class beyond
    // the trivial single-class case).
    if s.classes.len() > 1 {
        for c in &s.classes {
            println!(
                "  class {:14} {:4} reqs, attainment {:5.1}%, goodput {:.2} req/s, \
                 mean TTFT {:.1} ms, p99 TTFT {:.1} ms",
                c.name,
                c.requests,
                c.attainment * 100.0,
                c.goodput_rps,
                c.mean_ttft * 1e3,
                c.p99_ttft * 1e3,
            );
        }
    }
    0
}

fn cmd_real_serve(args: &[String]) -> i32 {
    const USAGE: &str = "usage: repro real-serve [--artifacts DIR] [--requests N]";
    if let Err(e) = reject_unknown_flags(args, &["--artifacts", "--requests"]) {
        eprintln!("{e}\n{USAGE}");
        return 2;
    }
    let dir = match flag_value(args, "--artifacts") {
        Ok(d) => d.unwrap_or("artifacts").to_string(),
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            return 2;
        }
    };
    let n = match parse_flag::<usize>(args, "--requests", 8) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            return 2;
        }
    };
    let mut engine = match PjrtLlmEngine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("failed to load artifacts from '{dir}': {e:#}");
            return 1;
        }
    };
    let dims = engine.dims();
    println!(
        "loaded tiny-Llama artifacts: {} slots, max_seq {}, vocab {}",
        dims.batch_slots, dims.max_seq, dims.vocab
    );
    // Manifest dims are user data: reject degenerate shapes gracefully
    // instead of tripping the generator's contract assert.
    if dims.vocab == 0 || dims.prompt_pad == 0 || dims.max_seq <= dims.prompt_pad {
        eprintln!(
            "artifact dims unsuitable for serving: vocab {}, prompt_pad {}, max_seq {} \
             (need vocab > 0 and max_seq > prompt_pad > 0)",
            dims.vocab, dims.prompt_pad, dims.max_seq
        );
        return 1;
    }
    let prompts = TokenPrompts::new(dims.vocab, dims.prompt_pad, dims.max_seq);
    for (req, prompt) in prompts.generate(n, 11) {
        if let Err(e) = engine.submit(req, prompt) {
            eprintln!("submit failed: {e:#}");
            return 1;
        }
    }
    match engine.run_to_completion() {
        Ok(s) => {
            println!(
                "served {} requests (REAL PJRT numerics): {:.1} tok/s, mean TTFT {:.1} ms, \
                 mean TPOT {:.1} ms, {} decode steps, {} tokens",
                s.requests,
                s.throughput_tps,
                s.mean_ttft * 1e3,
                s.mean_tpot * 1e3,
                engine.steps(),
                engine.tokens_generated()
            );
            0
        }
        Err(e) => {
            eprintln!("serving failed: {e:#}");
            1
        }
    }
}
