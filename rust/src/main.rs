//! `repro` — the leader entrypoint / CLI.
//!
//! ```text
//! repro list                       # show every reproducible table/figure
//! repro run <exp|all> [--csv]      # regenerate a paper table/figure
//! repro serve [--config f.json] [--requests N] [--rate R]
//!                                  # run the vLLM-style serving engine
//!                                  # (simulated backend) on a
//!                                  # Dynamic-Sonnet-like workload
//! repro real-serve [--artifacts d] # serve the REAL tiny-Llama artifacts
//!                                  # through PJRT (needs `make artifacts`)
//! ```

use cuda_myth::config::ServingConfig;
use cuda_myth::harness;
use cuda_myth::models::llama::LlamaConfig;
use cuda_myth::serving::cluster::ClusterSim;
use cuda_myth::serving::engine::{Engine, SimBackend};
use cuda_myth::serving::real_engine::PjrtLlmEngine;
use cuda_myth::serving::request::Request;
use cuda_myth::workload::DynamicSonnet;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("list") => cmd_list(),
        Some("run") => cmd_run(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("real-serve") => cmd_real_serve(&args[1..]),
        _ => {
            eprintln!("usage: repro <list|run <exp|all> [--csv]|serve [opts]|real-serve [opts]>");
            2
        }
    };
    std::process::exit(code);
}

fn cmd_list() -> i32 {
    println!("experiments (repro run <id>):");
    for e in harness::registry() {
        println!("  {:8} {}", e.id, e.title);
    }
    0
}

fn cmd_run(args: &[String]) -> i32 {
    let Some(id) = args.first() else {
        eprintln!("usage: repro run <exp|all> [--csv]");
        return 2;
    };
    let csv = args.iter().any(|a| a == "--csv");
    let reports = if id == "all" {
        harness::run_all()
    } else {
        match harness::run_experiment(id) {
            Some(r) => r,
            None => {
                eprintln!("unknown experiment '{id}' (see `repro list`)");
                return 2;
            }
        }
    };
    for r in reports {
        if csv {
            println!("# {}", r.title());
            print!("{}", r.to_csv());
        } else {
            r.print();
        }
    }
    0
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(|s| s.as_str())
}

fn cmd_serve(args: &[String]) -> i32 {
    let cfg = match flag_value(args, "--config") {
        Some(path) => match std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("{e}"))
            .and_then(|s| ServingConfig::from_json(&s))
        {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        },
        None => ServingConfig { num_blocks: 8192, ..Default::default() },
    };
    let n: usize = flag_value(args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(64);
    let rate: f64 =
        flag_value(args, "--rate").and_then(|v| v.parse().ok()).unwrap_or(f64::INFINITY);
    println!("serving config: {}", cfg.to_json());
    if cfg.replicas > 1 {
        // Data-parallel fleet behind the router (serving::cluster).
        let replicas = cfg.replicas;
        let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        for req in DynamicSonnet::default().generate(n, rate, 7) {
            sim.submit(req);
        }
        let s = sim.run_to_completion();
        println!(
            "served {} requests over {} replicas ({}): {:.1} tok/s, mean TTFT {:.1} ms, \
             p99 TTFT {:.1} ms, mean TPOT {:.2} ms, {} backpressure requeues",
            s.requests,
            replicas,
            cfg.route_policy.name(),
            s.throughput_tps,
            s.mean_ttft * 1e3,
            s.p99_ttft * 1e3,
            s.mean_tpot * 1e3,
            sim.requeues,
        );
        return 0;
    }
    let backend = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
    let mut engine = Engine::new(cfg, backend);
    for req in DynamicSonnet::default().generate(n, rate, 7) {
        engine.submit(req);
    }
    let s = engine.run_to_completion();
    println!(
        "served {} requests in {:.2}s (simulated): {:.1} tok/s, mean TTFT {:.1} ms, \
         mean TPOT {:.2} ms, p99 TTFT {:.1} ms",
        s.requests,
        engine.clock(),
        s.throughput_tps,
        s.mean_ttft * 1e3,
        s.mean_tpot * 1e3,
        s.p99_ttft * 1e3,
    );
    0
}

fn cmd_real_serve(args: &[String]) -> i32 {
    let dir = flag_value(args, "--artifacts").unwrap_or("artifacts").to_string();
    let n: usize = flag_value(args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(8);
    let mut engine = match PjrtLlmEngine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("failed to load artifacts from '{dir}': {e:#}");
            return 1;
        }
    };
    let dims = engine.dims();
    println!(
        "loaded tiny-Llama artifacts: {} slots, max_seq {}, vocab {}",
        dims.batch_slots, dims.max_seq, dims.vocab
    );
    for i in 0..n as u64 {
        let plen = 4 + (i as usize % 5);
        let prompt: Vec<i32> = (0..plen as i32).map(|t| (17 * t + i as i32 * 3) % 100).collect();
        let out_len = 8 + (i as usize % 8);
        if let Err(e) = engine.submit(Request::new(i, plen, out_len, 0.0), prompt) {
            eprintln!("submit failed: {e:#}");
            return 1;
        }
    }
    match engine.run_to_completion() {
        Ok(s) => {
            println!(
                "served {} requests (REAL PJRT numerics): {:.1} tok/s, mean TTFT {:.1} ms, \
                 mean TPOT {:.1} ms, {} decode steps, {} tokens",
                s.requests,
                s.throughput_tps,
                s.mean_ttft * 1e3,
                s.mean_tpot * 1e3,
                engine.steps(),
                engine.tokens_generated()
            );
            0
        }
        Err(e) => {
            eprintln!("serving failed: {e:#}");
            1
        }
    }
}
