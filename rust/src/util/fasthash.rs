//! Fast non-cryptographic hasher for integer keys (request ids, block
//! ids). std's default SipHash is DoS-resistant but ~5× slower than needed
//! for the block-manager hot path (§Perf opt-3); ids here are
//! engine-internal, so collision attacks are not a concern.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Fibonacci-multiply hasher (splitmix-style finalizer).
#[derive(Default)]
pub struct FastHasher {
    state: u64,
}

impl Hasher for FastHasher {
    #[inline]
    fn finish(&self) -> u64 {
        let mut z = self.state.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = self.state.rotate_left(8) ^ b as u64;
        }
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.state = self.state.rotate_left(32) ^ n;
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.write_u64(n as u64);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.write_u64(n as u64);
    }
}

/// HashMap with the fast hasher.
pub type FastMap<K, V> = HashMap<K, V, BuildHasherDefault<FastHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works_like_hashmap() {
        let mut m: FastMap<u64, u32> = FastMap::default();
        for i in 0..1000u64 {
            m.insert(i, (i * 3) as u32);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u64 {
            assert_eq!(m[&i], (i * 3) as u32);
        }
        m.remove(&500);
        assert!(!m.contains_key(&500));
    }

    #[test]
    fn hash_distributes_sequential_keys() {
        use std::hash::{BuildHasher, BuildHasherDefault};
        let bh: BuildHasherDefault<FastHasher> = Default::default();
        // Sequential ids must not collide in low bits (bucket index).
        let mut low_bits = std::collections::HashSet::new();
        for i in 0..64u64 {
            low_bits.insert(bh.hash_one(i) & 0x3F);
        }
        assert!(low_bits.len() > 32, "poor low-bit distribution: {}", low_bits.len());
    }
}
