//! Micro-benchmark harness — in-tree replacement for criterion (not
//! vendored offline). Used by every `benches/bench_*.rs` target
//! (`cargo bench` with `harness = false`).
//!
//! Method: warmup, then timed batches until both a minimum wall time and a
//! minimum iteration count are reached; reports mean/median/p95 per-iter
//! time and iterations/sec.

use std::time::{Duration, Instant};

use crate::util::stats::percentile;

/// One benchmark's measured distribution.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64()
    }
}

/// Benchmark runner with fixed time/iteration budgets.
pub struct Bencher {
    pub min_time: Duration,
    pub min_iters: u64,
    pub warmup_iters: u64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            min_time: Duration::from_millis(300),
            min_iters: 30,
            warmup_iters: 3,
            results: Vec::new(),
        }
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fast settings for CI (tests call this to keep runtime short).
    pub fn quick() -> Self {
        Bencher {
            min_time: Duration::from_millis(50),
            min_iters: 10,
            warmup_iters: 1,
            results: Vec::new(),
        }
    }

    /// Run one benchmark; `f` is a single iteration returning a value that
    /// gets black-boxed.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        let mut iters = 0u64;
        while start.elapsed() < self.min_time || iters < self.min_iters {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
            iters += 1;
            if iters > 10_000_000 {
                break;
            }
        }
        let mean_ns = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let r = BenchResult {
            name: name.to_string(),
            iters,
            mean: Duration::from_nanos(mean_ns as u64),
            median: Duration::from_nanos(percentile(&samples_ns, 50.0) as u64),
            p95: Duration::from_nanos(percentile(&samples_ns, 95.0) as u64),
        };
        println!(
            "bench {:<44} {:>10} iters  mean {:>12?}  median {:>12?}  p95 {:>12?}",
            r.name, r.iters, r.mean, r.median, r.p95
        );
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print a closing summary (benches call this from `main`).
    pub fn finish(&self, suite: &str) {
        println!("== bench suite '{suite}': {} benchmarks ==", self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::quick();
        let r = b.bench("spin", || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(black_box(i));
            }
            s
        });
        assert!(r.iters >= 10);
        assert!(r.mean.as_nanos() > 0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn faster_code_is_faster() {
        let mut b = Bencher::quick();
        let fast = b.bench("fast", || black_box(1u64) + 1).mean;
        let slow = b
            .bench("slow", || {
                let mut s = 0u64;
                for i in 0..50_000 {
                    s = s.wrapping_add(black_box(i));
                }
                s
            })
            .mean;
        assert!(slow > fast, "slow {slow:?} fast {fast:?}");
    }
}
