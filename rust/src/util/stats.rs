//! Streaming statistics used by the harness and the serving metrics.

/// Online mean/min/max/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

impl FromIterator<f64> for Summary {
    fn from_iter<T: IntoIterator<Item = f64>>(iter: T) -> Self {
        let mut s = Summary::new();
        for x in iter {
            s.add(x);
        }
        s
    }
}

/// Percentile over a sample set (nearest-rank on a sorted copy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile out of range");
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).floor() as usize;
    v[rank.min(v.len() - 1)]
}

/// Geometric mean; all inputs must be positive.
pub fn geomean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = samples.iter().map(|x| x.ln()).sum();
    (log_sum / samples.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mean_and_bounds() {
        let s: Summary = [1.0, 2.0, 3.0, 4.0].into_iter().collect();
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert_eq!(percentile(&v, 50.0), 50.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert_eq!(percentile(&v, 99.0), 99.0);
    }

    #[test]
    fn geomean_of_equal_values() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        // geomean(1, 4) = 2
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mean_basics() {
        assert!((mean(&[1.0, 3.0]) - 2.0).abs() < 1e-12);
        assert!(mean(&[]).is_nan());
    }
}
