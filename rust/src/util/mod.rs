//! Small shared utilities: statistics, the ASCII/CSV report renderer,
//! JSON, PRNG, unit helpers, and the dependency-free parallel executor.

pub mod benchkit;
pub mod fasthash;
pub mod json;
pub mod par;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod table;
pub mod units;

/// Ceiling division for positive integers.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Relative error |a-b| / max(|b|, eps).
#[inline]
pub fn rel_err(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
        assert_eq!(ceil_div(8192, 512), 16);
    }

    #[test]
    fn rel_err_basics() {
        assert!(rel_err(1.0, 1.0) < 1e-12);
        assert!((rel_err(1.1, 1.0) - 0.1).abs() < 1e-9);
    }
}
