//! Unit constants and conversions. Convention across the crate:
//! time in **seconds**, sizes in **bytes**, rates in **bytes/sec** or
//! **FLOP/s** — all `f64`.

pub const KIB: f64 = 1024.0;
pub const MIB: f64 = 1024.0 * 1024.0;
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

pub const KB: f64 = 1e3;
pub const MB: f64 = 1e6;
pub const GB: f64 = 1e9;
pub const TB: f64 = 1e12;

pub const GFLOPS: f64 = 1e9;
pub const TFLOPS: f64 = 1e12;

pub const US: f64 = 1e-6;
pub const MS: f64 = 1e-3;

/// Human-readable byte size.
pub fn fmt_bytes(b: f64) -> String {
    if b >= GIB {
        format!("{:.1}GiB", b / GIB)
    } else if b >= MIB {
        format!("{:.1}MiB", b / MIB)
    } else if b >= KIB {
        format!("{:.1}KiB", b / KIB)
    } else {
        format!("{:.0}B", b)
    }
}

/// Human-readable duration.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.2}s", s)
    } else if s >= MS {
        format!("{:.2}ms", s / MS)
    } else {
        format!("{:.1}us", s / US)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512.0), "512B");
        assert_eq!(fmt_bytes(2.0 * KIB), "2.0KiB");
        assert_eq!(fmt_bytes(3.5 * MIB), "3.5MiB");
        assert_eq!(fmt_bytes(96.0 * GIB), "96.0GiB");
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.5), "2.50s");
        assert_eq!(fmt_time(1.5 * MS), "1.50ms");
        assert_eq!(fmt_time(42.0 * US), "42.0us");
    }
}
