//! Minimal JSON parser/serializer — the offline environment vendors no
//! serde, so the artifact manifest (written by `python/compile/aot.py`) and
//! the serving config are handled by this in-tree implementation.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs (non-BMP
//! escapes map to U+FFFD). Numbers parse as f64.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field or error — for required manifest fields.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError(format!("missing field '{key}'")))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no Inf/NaN literal; emit null rather than
                    // an unparseable token.
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{}", x));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// JSON parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.i))
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"model":"tiny_llama","shapes":[[4,128],[4]],"pos":0,"ok":true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn non_finite_numbers_dump_as_null() {
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        // The output stays parseable JSON.
        assert_eq!(Json::parse(&Json::Num(f64::NEG_INFINITY).dump()).unwrap(), Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str(), Some("café ☕"));
        let out = Json::Str("tab\there".into()).dump();
        assert_eq!(out, r#""tab\there""#);
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"n": 42}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(42));
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert!(j.req("missing").is_err());
        assert!(j.req("n").is_ok());
    }
}
