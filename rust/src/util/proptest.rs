//! Tiny property-based testing harness — in-tree replacement for the
//! `proptest` crate (not vendored offline).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and checks `prop`; on failure it performs greedy shrinking via the
//! generator's `shrink` candidates and panics with the minimal
//! counterexample. Used by `rust/tests/proptests.rs` for the block-manager,
//! scheduler, collective and MME invariants.

use crate::util::prng::Rng;
use std::fmt::Debug;

/// A value generator with optional shrinking.
pub trait Gen {
    type Value: Clone + Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller values; default = no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Uniform usize in [lo, hi].
pub struct UsizeIn(pub usize, pub usize);

impl Gen for UsizeIn {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        rng.range(self.0 as u64, self.1 as u64) as usize
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut c = Vec::new();
        if *v > self.0 {
            c.push(self.0);
            c.push(self.0 + (*v - self.0) / 2);
            c.push(*v - 1);
        }
        c.dedup();
        c
    }
}

/// Uniform f64 in [lo, hi).
pub struct F64In(pub f64, pub f64);

impl Gen for F64In {
    type Value = f64;
    fn generate(&self, rng: &mut Rng) -> f64 {
        self.0 + rng.f64() * (self.1 - self.0)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        if *v > self.0 {
            vec![self.0, self.0 + (*v - self.0) / 2.0]
        } else {
            Vec::new()
        }
    }
}

/// Vec of a generator, with random length in [0, max_len].
pub struct VecOf<G: Gen>(pub G, pub usize);

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
        let len = rng.below(self.1 as u64 + 1) as usize;
        (0..len).map(|_| self.0.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut c = Vec::new();
        if !v.is_empty() {
            c.push(v[..v.len() / 2].to_vec());
            c.push(v[..v.len() - 1].to_vec());
            // Shrink one element.
            for cand in self.0.shrink(&v[0]) {
                let mut w = v.clone();
                w[0] = cand;
                c.push(w);
            }
        }
        c
    }
}

/// Pair of generators.
pub struct PairOf<A: Gen, B: Gen>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairOf<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut c: Vec<Self::Value> =
            self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
        c.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        c
    }
}

/// Run `prop` on `cases` random values; panic with a (shrunk)
/// counterexample on failure.
pub fn forall<G: Gen>(seed: u64, cases: usize, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if !prop(&v) {
            // Greedy shrink.
            let mut min = v.clone();
            'outer: loop {
                for cand in gen.shrink(&min) {
                    if !prop(&cand) {
                        min = cand;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!("property failed at case {case}: minimal counterexample = {min:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, 200, &UsizeIn(0, 100), |&x| x <= 100);
        forall(2, 200, &F64In(0.0, 1.0), |&x| (0.0..1.0).contains(&x));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        forall(3, 500, &UsizeIn(0, 1000), |&x| x < 900);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        let result = std::panic::catch_unwind(|| {
            forall(4, 500, &UsizeIn(0, 10_000), |&x| x < 500);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrink should land at exactly the boundary 500.
        assert!(msg.contains("= 500"), "msg: {msg}");
    }

    #[test]
    fn vec_and_pair_generators() {
        forall(5, 100, &VecOf(UsizeIn(1, 9), 16), |v| {
            v.len() <= 16 && v.iter().all(|&x| (1..=9).contains(&x))
        });
        forall(6, 100, &PairOf(UsizeIn(0, 4), F64In(-1.0, 1.0)), |(a, b)| {
            *a <= 4 && (-1.0..1.0).contains(b)
        });
    }
}
