//! Deterministic PRNG + distributions — in-tree replacement for `rand` /
//! `rand_distr` (not vendored in this offline environment).
//!
//! xoshiro256**: fast, high-quality, reproducible across platforms. The
//! workload generators (Zipf embedding indices, Dynamic-Sonnet length
//! mixture, Poisson arrivals) build on this.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 seed gives a well-mixed state.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Lemire's nearly-divisionless bounded sampling.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Exponential with rate lambda (inter-arrival times).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = (1.0 - self.f64()).max(1e-300);
        let u2 = self.f64();
        mean + std * (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Shuffle a slice in place (Fisher-Yates).
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len() as u64) as usize]
    }
}

/// Zipf-distributed sampler over [0, n) with exponent `s` — embedding table
/// lookups in RecSys follow a power law (hot items).
///
/// Uses rejection-inversion (Hörmann & Derflinger), O(1) per sample.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: f64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    dd: f64,
}

impl Zipf {
    pub fn new(n: u64, s: f64) -> Zipf {
        assert!(n > 0 && s > 0.0 && (s - 1.0).abs() > 1e-9, "s != 1 supported");
        let nf = n as f64;
        let h = |x: f64, s: f64| (x.powf(1.0 - s) - 1.0) / (1.0 - s);
        let h_x1 = h(1.5, s) - 1.0;
        let h_n = h(nf + 0.5, s);
        Zipf { n: nf, s, h_x1, h_n, dd: h_x1 - h(0.5, s) }
    }

    fn h_inv(&self, x: f64) -> f64 {
        (1.0 + x * (1.0 - self.s)).powf(1.0 / (1.0 - self.s))
    }

    /// Sample a rank in [0, n) (0 = hottest).
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        loop {
            let u = self.h_x1 + rng.f64() * (self.h_n - self.h_x1);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().clamp(1.0, self.n);
            let h = |x: f64| (x.powf(1.0 - self.s) - 1.0) / (1.0 - self.s);
            if u >= h(k + 0.5) - k.powf(-self.s) || u >= h(k + 0.5) - self.dd {
                return k as u64 - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_uniform_ish() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(3);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let x = r.range(5, 8);
            assert!((5..=8).contains(&x));
            saw_lo |= x == 5;
            saw_hi |= x == 8;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn exp_mean_close_to_inverse_rate() {
        let mut r = Rng::new(4);
        let mean: f64 = (0..50_000).map(|_| r.exp(2.0)).sum::<f64>() / 50_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
        assert!((var - 4.0).abs() < 0.15, "var {var}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let z = Zipf::new(1000, 1.2);
        let mut r = Rng::new(6);
        let mut head = 0usize;
        let n = 50_000;
        for _ in 0..n {
            let k = z.sample(&mut r);
            assert!(k < 1000);
            if k < 10 {
                head += 1;
            }
        }
        // With s=1.2, the top-10 of 1000 items draw a large share.
        assert!(head as f64 / n as f64 > 0.35, "head share {}", head as f64 / n as f64);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut v: Vec<u32> = (0..100).collect();
        Rng::new(9).shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
