//! ASCII/CSV renderers over the typed report model (`crate::report`) plus
//! the shared numeric formatters. The harness emits typed
//! `report::Report`s; this module turns them into the column-aligned
//! tables the CLI prints and the raw-number CSV used for plotting.

use crate::report::Report;

/// Column-aligned ASCII rendering — the `repro run <exp>` output.
pub fn render_ascii(r: &Report) -> String {
    let header = r.columns();
    let rows: Vec<Vec<String>> =
        r.rows().iter().map(|row| row.iter().map(|c| c.fmt()).collect()).collect();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in &rows {
        for (i, cell) in row.iter().enumerate() {
            if i >= widths.len() {
                widths.push(cell.len());
            } else {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("== {} ==\n", r.title()));
    if !header.is_empty() {
        let line: Vec<String> =
            header.iter().enumerate().map(|(i, h)| format!("{:>w$}", h, w = widths[i])).collect();
        out.push_str(&line.join("  "));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len().saturating_sub(1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
    }
    for row in &rows {
        let line: Vec<String> = row
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(c.len())))
            .collect();
        out.push_str(&line.join("  "));
        out.push('\n');
    }
    for note in r.notes() {
        out.push_str(&format!("  note: {}\n", note));
    }
    out
}

/// Quote a CSV field if it contains a delimiter, quote or newline
/// (RFC 4180), so labels like "Power (TDP, W)" stay one column.
fn csv_escape(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// CSV rendering with raw full-precision numbers (text cells pass
/// through, quoted when needed; the JSON artifact carries the units).
pub fn render_csv(r: &Report) -> String {
    let mut out = String::new();
    if !r.columns().is_empty() {
        let header: Vec<String> = r.columns().iter().map(|h| csv_escape(h)).collect();
        out.push_str(&header.join(","));
        out.push('\n');
    }
    for row in r.rows() {
        let fields: Vec<String> = row.iter().map(|c| csv_escape(&c.to_csv_field())).collect();
        out.push_str(&fields.join(","));
        out.push('\n');
    }
    out
}

/// Format a float with 3 significant-ish digits, fit for table cells.
pub fn fmt3(x: f64) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    let a = x.abs();
    if a == 0.0 {
        "0".to_string()
    } else if a >= 100.0 {
        format!("{:.0}", x)
    } else if a >= 10.0 {
        format!("{:.1}", x)
    } else if a >= 1.0 {
        format!("{:.2}", x)
    } else {
        format!("{:.3}", x)
    }
}

/// Format a ratio like "1.47x".
pub fn fmt_ratio(x: f64) -> String {
    format!("{:.2}x", x)
}

/// Format a fraction as a percentage like "64.2%".
pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::{Cell, Report, Unit};

    #[test]
    fn render_contains_rows_and_title() {
        let mut r = Report::new("Fig X");
        r.header(&["a", "bb"]);
        r.row(vec![Cell::count(1), Cell::count(2)]);
        r.row(vec![Cell::count(10), Cell::count(20)]);
        let s = r.render();
        assert!(s.contains("== Fig X =="));
        assert!(s.contains("bb"));
        assert!(s.contains("20"));
        assert_eq!(r.num_rows(), 2);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut r = Report::new("t");
        r.header(&["x", "y"]);
        r.row(vec![Cell::count(1), Cell::count(2)]);
        let csv = r.to_csv();
        assert_eq!(csv, "x,y\n1,2\n");
    }

    #[test]
    fn ascii_cells_are_the_typed_formatting() {
        let mut r = Report::new("t");
        r.header(&["shape", "util"]);
        r.row(vec![Cell::text("8192^3"), Cell::val(0.993, Unit::Percent)]);
        let s = r.render();
        assert!(s.contains("99.3%"), "{s}");
        // CSV carries the raw fraction, not the formatted percent.
        assert!(r.to_csv().contains("0.993"), "{}", r.to_csv());
    }

    #[test]
    fn csv_quotes_fields_with_delimiters() {
        let mut r = Report::new("t");
        r.header(&["metric", "v"]);
        r.row(vec![Cell::text("Power (TDP, W)"), Cell::count(400)]);
        r.row(vec![Cell::text("say \"hi\""), Cell::count(1)]);
        let csv = r.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[1], "\"Power (TDP, W)\",400");
        assert_eq!(lines[2], "\"say \"\"hi\"\"\",1");
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt3(0.0), "0");
        assert_eq!(fmt3(432.1), "432");
        assert_eq!(fmt3(43.21), "43.2");
        assert_eq!(fmt3(4.321), "4.32");
        assert_eq!(fmt3(0.4321), "0.432");
        assert_eq!(fmt_ratio(1.466), "1.47x");
        assert_eq!(fmt_pct(0.642), "64.2%");
    }
}
