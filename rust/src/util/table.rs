//! ASCII report tables — the harness prints the same rows/series the paper
//! reports, so every figure regenerator renders through this module.

/// A simple column-aligned table with a title, printed to stdout or rendered
/// to a string (the harness integration tests assert over the rendering).
#[derive(Debug, Clone)]
pub struct Report {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    notes: Vec<String>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Self {
        Report { title: title.into(), header: Vec::new(), rows: Vec::new(), notes: Vec::new() }
    }

    pub fn header(&mut self, cols: &[&str]) -> &mut Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cols: Vec<String>) -> &mut Self {
        self.rows.push(cols);
        self
    }

    pub fn note(&mut self, note: impl Into<String>) -> &mut Self {
        self.notes.push(note.into());
        self
    }

    pub fn title(&self) -> &str {
        &self.title
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Column-aligned rendering.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                if i >= widths.len() {
                    widths.push(cell.len());
                } else {
                    widths[i] = widths[i].max(cell.len());
                }
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        if !self.header.is_empty() {
            let line: Vec<String> = self
                .header
                .iter()
                .enumerate()
                .map(|(i, h)| format!("{:>w$}", h, w = widths[i]))
                .collect();
            out.push_str(&line.join("  "));
            out.push('\n');
            let total: usize = widths.iter().sum::<usize>() + 2 * widths.len().saturating_sub(1);
            out.push_str(&"-".repeat(total));
            out.push('\n');
        }
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(c.len())))
                .collect();
            out.push_str(&line.join("  "));
            out.push('\n');
        }
        for note in &self.notes {
            out.push_str(&format!("  note: {}\n", note));
        }
        out
    }

    /// Render as CSV (for plotting outside the harness).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        if !self.header.is_empty() {
            out.push_str(&self.header.join(","));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a float with 3 significant-ish digits, fit for table cells.
pub fn fmt3(x: f64) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    let a = x.abs();
    if a == 0.0 {
        "0".to_string()
    } else if a >= 100.0 {
        format!("{:.0}", x)
    } else if a >= 10.0 {
        format!("{:.1}", x)
    } else if a >= 1.0 {
        format!("{:.2}", x)
    } else {
        format!("{:.3}", x)
    }
}

/// Format a ratio like "1.47x".
pub fn fmt_ratio(x: f64) -> String {
    format!("{:.2}x", x)
}

/// Format a fraction as a percentage like "64.2%".
pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contains_rows_and_title() {
        let mut r = Report::new("Fig X");
        r.header(&["a", "bb"]);
        r.row(vec!["1".into(), "2".into()]);
        r.row(vec!["10".into(), "20".into()]);
        let s = r.render();
        assert!(s.contains("== Fig X =="));
        assert!(s.contains("bb"));
        assert!(s.contains("20"));
        assert_eq!(r.num_rows(), 2);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut r = Report::new("t");
        r.header(&["x", "y"]);
        r.row(vec!["1".into(), "2".into()]);
        let csv = r.to_csv();
        assert_eq!(csv, "x,y\n1,2\n");
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt3(0.0), "0");
        assert_eq!(fmt3(432.1), "432");
        assert_eq!(fmt3(43.21), "43.2");
        assert_eq!(fmt3(4.321), "4.32");
        assert_eq!(fmt3(0.4321), "0.432");
        assert_eq!(fmt_ratio(1.466), "1.47x");
        assert_eq!(fmt_pct(0.642), "64.2%");
    }
}
