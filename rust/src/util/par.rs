//! Dependency-free parallel execution: a `std::thread::scope` work pool
//! with deterministic, submission-ordered result assembly.
//!
//! Every sweep grid point in this repo is an independent, seeded
//! simulation — embarrassingly parallel work. [`par_map_indexed`] fans
//! closures across a bounded pool and returns the results **in
//! submission order**, so a parallel sweep emits byte-identical reports
//! (and therefore byte-identical `BENCH_*.json` artifacts) to a serial
//! one: parallelism is pure speed, never a semantics change. That
//! *jobs-invariance* is the layer's contract, pinned by a typed
//! `par_speed.jobs_invariance` claim and the integration tests.
//!
//! The worker budget resolves in three layers, innermost wins:
//!
//! 1. a thread-local override installed by [`with_jobs`] (scoped, used
//!    by tests and by the pool itself),
//! 2. the process-wide budget set once by [`configure_jobs`] (the CLI's
//!    `--jobs N` flag),
//! 3. [`available_jobs`] — `std::thread::available_parallelism`.
//!
//! Only the **first** parallel level fans out: worker threads run with
//! their budget clamped to 1, so `repro run all --jobs 8` parallelizes
//! across experiments while each experiment's inner grid stays serial
//! (no J x J thread explosion), and `repro run cluster-sweep --jobs 8`
//! — a single experiment — lets the grid itself use the budget.
//!
//! No rayon, no crossbeam: the crate vendors offline shims and adds no
//! dependencies, so the pool is ~100 lines of std.

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Process-wide worker budget; 0 = unset, fall through to
/// [`available_jobs`].
static GLOBAL_JOBS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Scoped per-thread override; 0 = inherit [`GLOBAL_JOBS`].
    static LOCAL_JOBS: Cell<usize> = const { Cell::new(0) };
}

/// The machine's available parallelism (>= 1); the default budget when
/// neither [`configure_jobs`] nor [`with_jobs`] applies.
pub fn available_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Set the process-wide worker budget (the CLI's `--jobs N`). Clamped to
/// >= 1; call once at startup, before any [`par_map_indexed`].
pub fn configure_jobs(n: usize) {
    GLOBAL_JOBS.store(n.max(1), Ordering::SeqCst);
}

/// The budget the *calling thread* would fan out to right now.
pub fn current_jobs() -> usize {
    let local = LOCAL_JOBS.with(|c| c.get());
    if local != 0 {
        return local;
    }
    match GLOBAL_JOBS.load(Ordering::SeqCst) {
        0 => available_jobs(),
        n => n,
    }
}

/// Run `f` with the calling thread's budget overridden to `n` (>= 1),
/// restoring the previous override afterwards — even on panic. Tests use
/// this instead of [`configure_jobs`] so concurrent `cargo test` threads
/// never race on the global.
pub fn with_jobs<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            LOCAL_JOBS.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(LOCAL_JOBS.with(|c| c.replace(n.max(1))));
    f()
}

/// Map `f` over `0..n` with up to [`current_jobs`] worker threads,
/// returning results **in submission order** (`out[i] == f(i)`).
///
/// Work is pulled from a shared atomic counter, so uneven grid points
/// balance across workers; each worker runs with its own budget clamped
/// to 1 (see the module docs). If any closure panics, the panic payload
/// of the **lowest panicking index** is re-raised on the caller after
/// all workers drain — deterministic regardless of thread timing, and
/// identical to the serial path's first panic.
pub fn par_map_indexed<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let jobs = current_jobs().min(n);
    if jobs <= 1 {
        // Serial path: run inline WITHOUT touching the budget, so a
        // single-experiment run (outer level n=1) leaves the whole
        // budget to its inner grid.
        return (0..n).map(&f).collect();
    }

    let slots: Vec<Mutex<Option<std::thread::Result<R>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| {
                // Workers are the leaf level: their own par calls run
                // serial (budget 1), preventing nested fan-out.
                with_jobs(1, || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = catch_unwind(AssertUnwindSafe(|| f(i)));
                    *slots[i].lock().unwrap() = Some(r);
                });
            });
        }
    });

    let mut out = Vec::with_capacity(n);
    let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
    for (i, slot) in slots.into_iter().enumerate() {
        match slot.into_inner().unwrap() {
            Some(Ok(r)) => out.push(r),
            Some(Err(payload)) => {
                if panic.is_none() {
                    panic = Some(payload);
                }
            }
            None => unreachable!("slot {i} never filled"),
        }
    }
    if let Some(payload) = panic {
        resume_unwind(payload);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_submission_order() {
        let serial: Vec<usize> = (0..100).map(|i| i * i).collect();
        let par = with_jobs(8, || par_map_indexed(100, |i| i * i));
        assert_eq!(par, serial);
    }

    #[test]
    fn empty_and_single_inputs() {
        assert_eq!(with_jobs(8, || par_map_indexed(0, |i| i)), Vec::<usize>::new());
        assert_eq!(with_jobs(8, || par_map_indexed(1, |i| i + 7)), vec![7]);
    }

    #[test]
    fn with_jobs_scopes_and_restores() {
        let outer = current_jobs();
        with_jobs(3, || {
            assert_eq!(current_jobs(), 3);
            with_jobs(5, || assert_eq!(current_jobs(), 5));
            assert_eq!(current_jobs(), 3);
        });
        assert_eq!(current_jobs(), outer);
    }

    #[test]
    fn with_jobs_restores_after_panic() {
        let before = current_jobs();
        let _ = catch_unwind(AssertUnwindSafe(|| {
            with_jobs(7, || panic!("boom"));
        }));
        assert_eq!(current_jobs(), before);
    }

    #[test]
    fn workers_run_with_budget_one() {
        // Only the first parallel level fans out: inside a worker the
        // budget reads 1, so nested par_map calls run inline.
        let seen = with_jobs(4, || par_map_indexed(8, |_| current_jobs()));
        assert_eq!(seen, vec![1; 8]);
    }

    #[test]
    fn serial_fallback_leaves_budget_for_inner_levels() {
        // n=1 at the outer level (a single experiment) must not eat the
        // budget: the inner level still sees it and parallelizes.
        let inner = with_jobs(4, || par_map_indexed(1, |_| current_jobs()));
        assert_eq!(inner, vec![4]);
    }

    #[test]
    fn lowest_index_panic_wins_deterministically() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            with_jobs(4, || {
                par_map_indexed(16, |i| {
                    if i == 3 || i == 11 {
                        panic!("grid point {i} failed");
                    }
                    i
                })
            })
        }));
        let payload = caught.expect_err("panic must propagate");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert_eq!(msg, "grid point 3 failed");
    }

    #[test]
    fn sibling_points_complete_despite_a_panic() {
        // A panicking grid point must not poison its siblings: every
        // other index still computes (observable via the side counter).
        let done = AtomicUsize::new(0);
        let _ = catch_unwind(AssertUnwindSafe(|| {
            with_jobs(4, || {
                par_map_indexed(32, |i| {
                    if i == 0 {
                        panic!("first point fails");
                    }
                    done.fetch_add(1, Ordering::Relaxed);
                })
            })
        }));
        assert_eq!(done.load(Ordering::Relaxed), 31);
    }
}
