#!/usr/bin/env python3
"""Render latency/goodput curves from ``BENCH_*.json`` experiment artifacts.

The Rust harness (``repro run all --json --out DIR``) writes one typed
artifact per experiment (schema ``cuda-myth/experiment-v1``): every report
cell is either a text label or ``{"v": <raw f64>, "unit": "tok/s"}``.
This script consumes those raw numbers directly — no CSV scraping, no
re-parsing of formatted strings — and emits one PNG per plottable report
(>= 2 rows and >= 1 numeric column), e.g. the ``cluster_sweep``
latency-vs-load frontier curves and the ``cache_sweep`` hit-rate/goodput
vs capacity curves.

Artifacts whose reports carry per-traffic-class attainment columns
(column names ending in " att" with unit "frac", e.g. the ``qos_sweep``
class-mix grid) additionally get one combined per-class attainment
figure overlaying every class's curve across all sweep reports (one
linestyle per report/mix, one color per class).

``BENCH_sim_speed.json`` (the simulator's self-benchmark) additionally
gets an events/sec trend figure: one line per event loop (indexed core
vs scan-loop oracle, plus the macro-stepping fast path vs its retained
micro-step oracle when the artifact carries the macro throughput
report). Pass several artifact directories — one per commit, oldest
first — and the trend spans them; a single directory yields
single-point series (the CI smoke shape).

``BENCH_chaos_sweep.json`` (the fault-injection grid) gets one
dip/recovery timeline figure per fleet: goodput over time, one line per
fault schedule, with each schedule's fault windows (crash downtime,
straggler interval, preemption storm) shaded behind its curve.

``BENCH_tp_sweep.json`` (the device-group scaling grid) additionally
gets one combined tokens/s-vs-tp scaling figure: one solid curve per
device kind from its ``TP sweep [<device>]`` report, with each device's
ideal linear scaling from its tp=1 point drawn as a dotted reference —
the gap between the two is the all-reduce overhead.

``BENCH_fleet_budget.json`` (the fixed-card-budget sweep) additionally
gets one goodput-per-card-vs-fleet-shape figure from its ``Fleet-budget
goodput frontier`` report: the four 8-card shapes (8x tp1 ... 1x tp8) on
a categorical x-axis, one line per device kind — the capacity planner's
view of how to slice a node.

Usage:
    python python/plot_bench.py <artifact-dir> [<older-dir> ...] [--out <plot-dir>]

Per-report figures are rendered from the first directory; the sim-speed
trend spans every directory given, in order.

Exit codes: 0 on success, 2 when the first directory holds no artifacts
(so a CI smoke step fails loudly if the producer broke).
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

SCHEMA = "cuda-myth/experiment-v1"

# Units drawn as curves (y-axes); anything else (counts, labels) is
# context, not a metric worth a line.
CURVE_UNITS = {
    "s", "ms", "tok/s", "req/s", "ev/s", "frac", "J/tok", "J", "TFLOPS",
    "GFLOPS", "GiB/s", "GB/s", "TB/s", "ratio", "W",
}


def slugify(text: str, max_len: int = 60) -> str:
    slug = re.sub(r"[^a-zA-Z0-9]+", "-", text).strip("-").lower()
    return slug[:max_len] or "report"


def numeric_columns(report: dict) -> list[tuple[int, str, str]]:
    """(index, column name, unit) for columns whose cells are values."""
    header = report.get("columns", [])
    rows = report.get("rows", [])
    out = []
    for idx, name in enumerate(header):
        units = {
            cell.get("unit")
            for row in rows
            if idx < len(row) and isinstance((cell := row[idx]), dict)
        }
        if len(units) == 1:
            out.append((idx, name, units.pop()))
    return out


def column_values(report: dict, idx: int) -> list[float]:
    # Mirror numeric_columns' short-row tolerance: the schema does not
    # force every row to be as wide as the header.
    return [
        float(cell["v"])
        if idx < len(row) and isinstance(cell := row[idx], dict)
        else float("nan")
        for row in report.get("rows", [])
    ]


def plot_report(experiment: str, report: dict, out_dir: Path) -> Path | None:
    cols = numeric_columns(report)
    curves = [(i, name, unit) for i, name, unit in cols if unit in CURVE_UNITS]
    if len(report.get("rows", [])) < 2 or not curves:
        return None

    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    # X axis: the first numeric column (offered load, capacity, ...) when
    # one exists, otherwise the row index labeled by the first cell.
    if cols:
        x_idx, x_name, x_unit = cols[0]
        xs = column_values(report, x_idx)
        x_label = f"{x_name} [{x_unit}]"
        curves = [c for c in curves if c[0] != x_idx] or curves
    else:  # pragma: no cover - curves nonempty implies cols nonempty
        xs = list(range(len(report.get("rows", []))))
        x_label = "row"

    fig, ax = plt.subplots(figsize=(7, 4.5))
    twin = None
    # Group curves by unit; first unit on the left axis, one twin right
    # axis for the second unit, further units skipped (still listed in
    # the legend note).
    units_in_order: list[str] = []
    for _, _, unit in curves:
        if unit not in units_in_order:
            units_in_order.append(unit)
    for i, name, unit in curves:
        ys = column_values(report, i)
        if unit == units_in_order[0]:
            ax.plot(xs, ys, marker="o", label=f"{name} [{unit}]")
        elif len(units_in_order) > 1 and unit == units_in_order[1]:
            if twin is None:
                twin = ax.twinx()
                twin.set_ylabel(units_in_order[1])
            twin.plot(xs, ys, marker="s", linestyle="--", label=f"{name} [{unit}]")
    ax.set_xlabel(x_label)
    ax.set_ylabel(units_in_order[0])
    ax.set_title(f"{experiment}: {report.get('title', '')}"[:100])
    handles, labels = ax.get_legend_handles_labels()
    if twin is not None:
        h2, l2 = twin.get_legend_handles_labels()
        handles += h2
        labels += l2
    if handles:
        ax.legend(handles, labels, fontsize=7)
    ax.grid(True, alpha=0.3)
    fig.tight_layout()

    out = out_dir / f"{experiment}__{slugify(report.get('title', 'report'))}.png"
    fig.savefig(out, dpi=110)
    plt.close(fig)
    return out


def class_attainment_columns(report: dict) -> list[tuple[int, str]]:
    """(index, class name) for per-class attainment columns: names ending
    in " att" with the fraction unit — the shape the qos_sweep per-mix
    reports emit ("interactive att", "batch att", ...)."""
    return [
        (idx, name[: -len(" att")])
        for idx, name, unit in numeric_columns(report)
        if unit == "frac"
        and name.endswith(" att")
        and not name.startswith("blind ")
        and name != "weighted att"
    ]


def plot_class_attainment(experiment: str, artifact: dict, out_dir: Path) -> Path | None:
    """One combined figure overlaying every class's attainment curve from
    every report that carries >= 2 per-class attainment columns (one
    linestyle per report, one color per class)."""
    sweeps = [
        (report, cols)
        for report in artifact.get("reports", [])
        if len((cols := class_attainment_columns(report))) >= 2
        and len(report.get("rows", [])) >= 2
    ]
    if not sweeps:
        return None

    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, ax = plt.subplots(figsize=(7.5, 4.5))
    linestyles = ["-", "--", ":", "-."]
    color_by_class: dict[str, str] = {}
    cycle = plt.rcParams["axes.prop_cycle"].by_key().get("color", ["C0", "C1", "C2"])
    x_label = "row"
    for si, (report, cols) in enumerate(sweeps):
        numeric = numeric_columns(report)
        x_idx, x_name, x_unit = numeric[0]
        xs = column_values(report, x_idx)
        x_label = f"{x_name} [{x_unit}]"
        ls = linestyles[si % len(linestyles)]
        for idx, cls in cols:
            color = color_by_class.setdefault(cls, cycle[len(color_by_class) % len(cycle)])
            label = cls if si == 0 else None  # one legend entry per class
            ax.plot(xs, column_values(report, idx), ls, marker="o", ms=3, color=color, label=label)
    ax.set_xlabel(x_label)
    ax.set_ylabel("SLO attainment [frac]")
    ax.set_ylim(-0.02, 1.05)
    ax.set_title(f"{experiment}: per-class attainment ({len(sweeps)} sweeps overlaid)"[:100])
    ax.legend(fontsize=8, title="traffic class")
    ax.grid(True, alpha=0.3)
    fig.tight_layout()
    out = out_dir / f"{experiment}__per-class-attainment.png"
    fig.savefig(out, dpi=110)
    plt.close(fig)
    return out


CHAOS_TIMELINE_PREFIX = "Chaos goodput timeline"
CHAOS_WINDOW_COLORS = {"crash": "tab:red", "straggler": "tab:orange", "preempt_storm": "tab:purple"}


def chaos_fault_windows(artifact: dict) -> list[tuple[str, str, float, float]]:
    """(schedule, kind, from_s, until_s) rows of the fault-window report
    the chaos_sweep experiment emits alongside its timelines."""
    report = next(
        (r for r in artifact.get("reports", []) if r.get("title") == "Chaos fault windows"),
        None,
    )
    if report is None:
        return []
    return [
        (row[0], row[1], float(row[2]["v"]), float(row[3]["v"]))
        for row in report.get("rows", [])
        if len(row) >= 4
        and isinstance(row[0], str)
        and isinstance(row[1], str)
        and isinstance(row[2], dict)
        and isinstance(row[3], dict)
    ]


def plot_chaos_timeline(experiment: str, artifact: dict, report: dict, out_dir: Path) -> Path | None:
    """Goodput-over-time dip/recovery figure for one chaos timeline
    report: one line per fault schedule (the text label of each row),
    the schedule's fault windows shaded behind the curves."""
    series: dict[str, tuple[list[float], list[float]]] = {}
    for row in report.get("rows", []):
        if (
            len(row) >= 3
            and isinstance(row[0], str)
            and isinstance(row[1], dict)
            and isinstance(row[2], dict)
        ):
            ts, gs = series.setdefault(row[0], ([], []))
            ts.append(float(row[1]["v"]))
            gs.append(float(row[2]["v"]))
    if not series or all(len(ts) < 2 for ts, _ in series.values()):
        return None

    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, ax = plt.subplots(figsize=(7.5, 4.5))
    for label, (ts, gs) in series.items():
        ax.plot(ts, gs, marker="o", ms=3, label=label)
    seen_kinds: set[str] = set()
    for schedule, kind, start, until in chaos_fault_windows(artifact):
        if schedule not in series:
            continue
        span_label = kind if kind not in seen_kinds else None
        seen_kinds.add(kind)
        ax.axvspan(
            start,
            max(until, start + 0.05),  # zero-width storms still visible
            alpha=0.15,
            color=CHAOS_WINDOW_COLORS.get(kind, "gray"),
            label=span_label,
        )
    ax.set_xlabel("time [s]")
    ax.set_ylabel("goodput [req/s]")
    ax.set_title(f"{experiment}: {report.get('title', '')}"[:100])
    ax.legend(fontsize=7, title="schedule / fault window")
    ax.grid(True, alpha=0.3)
    fig.tight_layout()
    out = out_dir / f"{experiment}__{slugify(report.get('title', 'chaos-timeline'))}.png"
    fig.savefig(out, dpi=110)
    plt.close(fig)
    return out


TP_REPORT_RE = re.compile(r"^TP sweep \[(?P<device>[^\]]+)\]")


def tp_scaling_series(artifact: dict) -> list[tuple[str, list[int], list[float]]]:
    """(device, tp values, tok/s values) per ``TP sweep [<device>]``
    report: rows labeled ``tp=<n>`` with a tok/s column — the shape the
    tp_sweep per-device reports emit."""
    series = []
    for report in artifact.get("reports", []):
        m = TP_REPORT_RE.match(report.get("title", ""))
        if m is None:
            continue
        tok_cols = [idx for idx, _, unit in numeric_columns(report) if unit == "tok/s"]
        if not tok_cols:
            continue
        tps: list[int] = []
        ys: list[float] = []
        for row, v in zip(report.get("rows", []), column_values(report, tok_cols[0])):
            label = row[0] if row and isinstance(row[0], str) else ""
            if not label.startswith("tp="):
                continue
            try:
                tps.append(int(label[len("tp="):]))
            except ValueError:
                continue
            ys.append(v)
        if len(tps) >= 2:
            series.append((m.group("device"), tps, ys))
    return series


def plot_tp_scaling(experiment: str, artifact: dict, out_dir: Path) -> Path | None:
    """One combined tokens/s-vs-tp figure: a solid measured curve per
    device kind plus its dotted ideal-linear reference anchored at the
    tp=1 point, so sub-linear scaling (the all-reduce tax) is the visible
    gap between the pair."""
    series = tp_scaling_series(artifact)
    if not series:
        return None

    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, ax = plt.subplots(figsize=(7, 4.5))
    cycle = plt.rcParams["axes.prop_cycle"].by_key().get("color", ["C0", "C1", "C2"])
    for i, (device, tps, ys) in enumerate(series):
        color = cycle[i % len(cycle)]
        ax.plot(tps, ys, marker="o", color=color, label=device)
        ax.plot(tps, [ys[0] * tp / tps[0] for tp in tps], ":", color=color, alpha=0.6,
                label=f"{device} (ideal linear)")
    ax.set_xscale("log", base=2)
    ax.set_xticks(series[0][1])
    ax.set_xticklabels([str(tp) for tp in series[0][1]])
    ax.set_xlabel("tensor-parallel group width (cards per replica)")
    ax.set_ylabel("throughput [tok/s]")
    ax.set_title(f"{experiment}: tokens/s vs tp per device kind"[:100])
    ax.legend(fontsize=8)
    ax.grid(True, alpha=0.3)
    fig.tight_layout()
    out = out_dir / f"{experiment}__tp-scaling.png"
    fig.savefig(out, dpi=110)
    plt.close(fig)
    return out


FLEET_FRONTIER_TITLE = "Fleet-budget goodput frontier"
FLEET_DEVICE_COL_SUFFIX = " goodput/card"


def fleet_frontier_series(artifact: dict) -> tuple[list[str], list[tuple[str, list[float]]]]:
    """(shape labels, [(device, goodput-per-card values)]) from the
    fleet-budget frontier report: text rows are the 8-card shapes, each
    ``<device> goodput/card`` column is one device's curve."""
    report = next(
        (r for r in artifact.get("reports", []) if r.get("title") == FLEET_FRONTIER_TITLE),
        None,
    )
    if report is None:
        return [], []
    shapes = [
        row[0] if row and isinstance(row[0], str) else f"row {i}"
        for i, row in enumerate(report.get("rows", []))
    ]
    series = [
        (name[: -len(FLEET_DEVICE_COL_SUFFIX)], column_values(report, idx))
        for idx, name, _unit in numeric_columns(report)
        if name.endswith(FLEET_DEVICE_COL_SUFFIX)
    ]
    return shapes, series


def plot_fleet_budget(experiment: str, artifact: dict, out_dir: Path) -> Path | None:
    """Goodput-per-card vs fleet shape: the four ways to slice the 8-card
    node on a categorical x-axis, one line per device kind. Infeasible
    shapes (tp=1 for 70B) sit at zero — the visible cliff."""
    shapes, series = fleet_frontier_series(artifact)
    if len(shapes) < 2 or not series:
        return None

    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, ax = plt.subplots(figsize=(7, 4.5))
    xs = list(range(len(shapes)))
    for device, ys in series:
        ax.plot(xs, ys, marker="o", label=device)
    ax.set_xticks(xs)
    ax.set_xticklabels(shapes)
    ax.set_xlabel("fleet shape (replicas x tensor-parallel width, 8 cards total)")
    ax.set_ylabel("goodput per card [req/s]")
    ax.set_title(f"{experiment}: goodput/card vs fleet shape (heavy load)"[:100])
    ax.legend(fontsize=8, title="device")
    ax.grid(True, alpha=0.3)
    fig.tight_layout()
    out = out_dir / f"{experiment}__fleet-shape-frontier.png"
    fig.savefig(out, dpi=110)
    plt.close(fig)
    return out


SIM_SPEED_THROUGHPUT_TITLES = ("Sim-speed throughput", "Sim-speed macro-stepping throughput")


def sim_speed_throughput_rows(artifact: dict) -> list[tuple[str, float]]:
    """(event-loop label, events/sec) pairs from every timed-throughput
    report in a sim-speed artifact: the indexed-vs-scan pair, plus the
    macro-vs-micro pair when present (older artifacts predate it). Row
    labels are unique across the reports, so they name the series."""
    pairs: list[tuple[str, float]] = []
    for report in artifact.get("reports", []):
        title = report.get("title", "")
        if not any(title.startswith(t) for t in SIM_SPEED_THROUGHPUT_TITLES):
            continue
        ev_cols = [
            idx
            for idx, name, unit in numeric_columns(report)
            if unit == "ev/s" and name == "events/sec"
        ]
        if not ev_cols:
            continue
        for row, v in zip(report.get("rows", []), column_values(report, ev_cols[0])):
            loop = row[0] if row and isinstance(row[0], str) else "?"
            pairs.append((loop, v))
    return pairs


def plot_sim_speed_trend(artifact_dirs: list[Path], out_dir: Path) -> Path | None:
    """Events/sec trend for the sim-speed self-benchmark: one line per
    event loop (row labels of the timed-throughput reports, macro-step
    series included) across the given artifact directories in order — a
    commit history when the caller keeps one directory per commit,
    single-point series for one dir."""
    series: dict[str, list[float]] = {}
    labels: list[str] = []
    for d in artifact_dirs:
        path = d / "BENCH_sim_speed.json"
        if not path.exists():
            continue
        artifact = json.loads(path.read_text())
        if artifact.get("schema") != SCHEMA:
            continue
        pairs = sim_speed_throughput_rows(artifact)
        if not pairs:
            continue
        labels.append(d.name)
        for loop, v in pairs:
            # Pad a loop first seen now with NaNs for the earlier dirs.
            series.setdefault(loop, [float("nan")] * (len(labels) - 1)).append(v)
        for vals in series.values():
            if len(vals) < len(labels):  # loop absent from this dir
                vals.append(float("nan"))
    if not series:
        return None

    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, ax = plt.subplots(figsize=(7, 4.5))
    xs = list(range(len(labels)))
    for loop, vals in series.items():
        ax.plot(xs, vals, marker="o", label=loop)
    ax.set_xticks(xs)
    ax.set_xticklabels(labels, rotation=30, ha="right", fontsize=7)
    ax.set_xlabel("artifact directory (commit order)")
    ax.set_ylabel("simulated events per wall-clock second [ev/s]")
    ax.set_title("sim_speed: dispatch throughput trend")
    ax.legend(fontsize=8, title="event loop")
    ax.grid(True, alpha=0.3)
    fig.tight_layout()
    out = out_dir / "sim_speed__events-per-sec-trend.png"
    fig.savefig(out, dpi=110)
    plt.close(fig)
    return out


def plot_artifact(path: Path, out_dir: Path) -> list[Path]:
    artifact = json.loads(path.read_text())
    schema = artifact.get("schema")
    if schema != SCHEMA:
        print(f"  skipping {path.name}: unknown schema {schema!r}", file=sys.stderr)
        return []
    experiment = artifact.get("experiment", path.stem)
    written = []
    for report in artifact.get("reports", []):
        if report.get("title", "").startswith(CHAOS_TIMELINE_PREFIX):
            # Dedicated dip/recovery rendering (fault windows shaded, one
            # line per schedule) replaces the generic per-report curves,
            # which would concatenate every schedule into one jagged line.
            out = plot_chaos_timeline(experiment, artifact, report, out_dir)
        else:
            out = plot_report(experiment, report, out_dir)
        if out is not None:
            written.append(out)
    combined = plot_class_attainment(experiment, artifact, out_dir)
    if combined is not None:
        written.append(combined)
    scaling = plot_tp_scaling(experiment, artifact, out_dir)
    if scaling is not None:
        written.append(scaling)
    frontier = plot_fleet_budget(experiment, artifact, out_dir)
    if frontier is not None:
        written.append(frontier)
    return written


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "artifact_dir",
        nargs="+",
        help="director(ies) holding BENCH_*.json artifacts; per-report plots "
        "come from the first, the sim-speed trend spans all (commit order)",
    )
    ap.add_argument("--out", default=None, help="plot output directory (default: <artifact-dir>/plots)")
    args = ap.parse_args(argv)

    dirs = [Path(d) for d in args.artifact_dir]
    artifact_dir = dirs[0]
    artifacts = sorted(artifact_dir.glob("BENCH_*.json"))
    if not artifacts:
        print(f"no BENCH_*.json artifacts in '{artifact_dir}'", file=sys.stderr)
        return 2

    out_dir = Path(args.out) if args.out else artifact_dir / "plots"
    out_dir.mkdir(parents=True, exist_ok=True)

    total = 0
    for path in artifacts:
        written = plot_artifact(path, out_dir)
        total += len(written)
        for w in written:
            print(f"wrote {w}")
    trend = plot_sim_speed_trend(dirs, out_dir)
    if trend is not None:
        total += 1
        print(f"wrote {trend}")
    print(f"{total} plot(s) from {len(artifacts)} artifact(s) -> {out_dir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
