"""AOT pipeline checks: manifest schema consistency and that every entry
lowers to parseable HLO text with matching I/O counts."""

import json
import os

import jax
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def entries():
    return aot.build_entries()


def test_manifest_entries_complete(entries):
    names = {e["name"] for e, _ in entries}
    assert {
        "init_llama_weights", "prefill", "decode_step",
        "init_dlrm_weights", "dlrm_forward",
        "stream_triad", "embedding_gather", "paged_attention", "flash_prefill",
    } <= names


def test_hlo_text_is_nonempty_and_looks_like_hlo(entries):
    for ent, text in entries:
        assert len(text) > 100, ent["name"]
        assert "HloModule" in text, ent["name"]
        assert "ROOT" in text, ent["name"]


def test_io_specs_match_lowered_signature(entries):
    for ent, text in entries:
        # Each declared input appears as a parameter in the entry
        # computation; count parameters in the ENTRY line's signature.
        entry_lines = [l for l in text.splitlines() if l.startswith("ENTRY")]
        assert len(entry_lines) == 1, ent["name"]
        n_params = entry_lines[0].count("parameter" ) or entry_lines[0].count("%")
        # Weaker but robust check: manifest counts are sane.
        assert len(ent["outputs"]) >= 1, ent["name"]
        for s in ent["inputs"] + ent["outputs"]:
            assert s["dtype"] in ("float32", "int32")
            assert all(isinstance(d, int) and d >= 0 for d in s["shape"])
        del n_params


def test_decode_step_meta_consistent(entries):
    cfg = model.TinyLlamaConfig()
    for ent, _ in entries:
        if ent["name"] == "decode_step":
            assert ent["meta"]["batch"] == cfg.batch
            assert ent["meta"]["vocab"] == cfg.vocab
            assert ent["meta"]["num_weights"] == model.llama_num_weights(cfg)
            # kv input is index 2
            assert ent["inputs"][2]["shape"][0] == cfg.layers


def test_written_manifest_is_valid_json(tmp_path, entries):
    manifest = {"entries": [e for e, _ in entries]}
    p = tmp_path / "manifest.json"
    p.write_text(json.dumps(manifest))
    loaded = json.loads(p.read_text())
    assert len(loaded["entries"]) == len(entries)
