"""Tests for python/plot_bench.py against a synthetic cuda-myth/experiment-v1
artifact — the same schema `repro run all --json --out DIR` writes, so the
CI smoke step (`python python/plot_bench.py bench-artifacts`) is covered
without needing the Rust binary."""

import json
import sys
from pathlib import Path

import pytest

pytest.importorskip("matplotlib")

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
import plot_bench  # noqa: E402


def val(x, unit):
    return {"v": x, "unit": unit}


def synthetic_artifact():
    return {
        "schema": "cuda-myth/experiment-v1",
        "experiment": "cache_sweep",
        "title": "synthetic",
        "params": {"seed": 23},
        "reports": [
            {
                "title": "Prefix-cache capacity sweep [warm: 8 groups]",
                "columns": ["capacity", "blocks", "hit rate", "tok/s", "p99 TTFT s"],
                "rows": [
                    ["off", val(0, "count"), val(0.0, "frac"), val(900.0, "tok/s"), val(0.9, "s")],
                    ["64 blk", val(64, "count"), val(0.55, "frac"), val(980.0, "tok/s"), val(0.7, "s")],
                    ["unbounded", val(8192, "count"), val(0.9, "frac"), val(1050.0, "tok/s"), val(0.5, "s")],
                ],
                "notes": ["synthetic"],
            },
            {
                # Text-only report: nothing to plot, must be skipped.
                "title": "Cache-sweep derived claims",
                "columns": ["claim", "value"],
                "rows": [["parity", val(0.0, "s")]],
                "notes": [],
            },
        ],
        "expectations": [],
    }


def test_numeric_columns_and_values():
    report = synthetic_artifact()["reports"][0]
    cols = plot_bench.numeric_columns(report)
    names = [name for _, name, _ in cols]
    assert names == ["blocks", "hit rate", "tok/s", "p99 TTFT s"]
    units = {name: unit for _, name, unit in cols}
    assert units["hit rate"] == "frac"
    idx = next(i for i, name, _ in cols if name == "tok/s")
    assert plot_bench.column_values(report, idx) == [900.0, 980.0, 1050.0]


def test_plots_rendered_from_artifact_dir(tmp_path):
    art_dir = tmp_path / "bench"
    art_dir.mkdir()
    (art_dir / "BENCH_cache_sweep.json").write_text(json.dumps(synthetic_artifact()))
    out_dir = tmp_path / "plots"
    assert plot_bench.main([str(art_dir), "--out", str(out_dir)]) == 0
    pngs = sorted(out_dir.glob("*.png"))
    assert len(pngs) == 1, pngs
    assert pngs[0].name.startswith("cache_sweep__prefix-cache-capacity-sweep")
    assert pngs[0].stat().st_size > 1000


def test_ragged_rows_do_not_crash(tmp_path):
    # The artifact schema does not force every row to be header-width;
    # short rows must become NaN points, not IndexErrors.
    art = synthetic_artifact()
    art["reports"][0]["rows"].append(["truncated"])
    import math

    vals = plot_bench.column_values(art["reports"][0], 3)
    assert math.isnan(vals[-1]) and vals[0] == 900.0
    art_dir = tmp_path / "bench"
    art_dir.mkdir()
    (art_dir / "BENCH_cache_sweep.json").write_text(json.dumps(art))
    assert plot_bench.main([str(art_dir), "--out", str(tmp_path / "plots")]) == 0


def test_empty_dir_fails_loudly(tmp_path):
    assert plot_bench.main([str(tmp_path)]) == 2


def test_unknown_schema_is_skipped(tmp_path):
    (tmp_path / "BENCH_x.json").write_text(json.dumps({"schema": "other", "reports": []}))
    out_dir = tmp_path / "plots"
    assert plot_bench.main([str(tmp_path), "--out", str(out_dir)]) == 0
    assert not list(out_dir.glob("*.png"))


def qos_artifact():
    def sweep(title, base):
        return {
            "title": title,
            "columns": [
                "offered", "offered req/s", "interactive att", "batch att",
                "background att", "weighted att", "blind interactive att",
            ],
            "rows": [
                [
                    f"{rps} rps", val(rps, "req/s"),
                    val(min(1.0, base + 0.2 - i * 0.2), "frac"),
                    val(min(1.0, base + 0.3 - i * 0.1), "frac"),
                    val(1.0, "frac"),
                    val(min(1.0, base + 0.1 - i * 0.15), "frac"),
                    val(min(1.0, base - i * 0.3), "frac"),
                ]
                for i, rps in enumerate([8, 16, 24])
            ],
            "notes": [],
        }

    return {
        "schema": "cuda-myth/experiment-v1",
        "experiment": "qos_sweep",
        "title": "synthetic qos",
        "params": {"seed": 31},
        "reports": [
            sweep("QoS load sweep [interactive-heavy 70/20/10]", 0.8),
            sweep("QoS load sweep [balanced 40/30/30]", 0.7),
            {
                "title": "QoS-sweep derived claims",
                "columns": ["claim", "value"],
                "rows": [["parity", val(0.0, "s")]],
                "notes": [],
            },
        ],
        "expectations": [],
    }


def test_class_attainment_columns_detected():
    report = qos_artifact()["reports"][0]
    cols = plot_bench.class_attainment_columns(report)
    names = [name for _, name in cols]
    # The "blind" control column is excluded; the x column is not " att".
    assert names == ["interactive", "batch", "background"]


def test_qos_artifact_gets_combined_class_figure(tmp_path):
    art_dir = tmp_path / "bench"
    art_dir.mkdir()
    (art_dir / "BENCH_qos_sweep.json").write_text(json.dumps(qos_artifact()))
    out_dir = tmp_path / "plots"
    assert plot_bench.main([str(art_dir), "--out", str(out_dir)]) == 0
    combined = out_dir / "qos_sweep__per-class-attainment.png"
    assert combined.exists(), sorted(out_dir.glob("*.png"))
    assert combined.stat().st_size > 1000
    # The per-report generic curves are still rendered alongside.
    assert len(list(out_dir.glob("qos_sweep__qos-load-sweep*.png"))) == 2


def test_no_combined_figure_without_class_columns(tmp_path):
    # The cache_sweep synthetic artifact has no " att" columns: the
    # combined per-class figure must not appear.
    art_dir = tmp_path / "bench"
    art_dir.mkdir()
    (art_dir / "BENCH_cache_sweep.json").write_text(json.dumps(synthetic_artifact()))
    out_dir = tmp_path / "plots"
    assert plot_bench.main([str(art_dir), "--out", str(out_dir)]) == 0
    assert not (out_dir / "cache_sweep__per-class-attainment.png").exists()


def sim_speed_artifact(indexed_ev_s=5.0e6, oracle_ev_s=4.0e5, with_macro=False):
    cols = [
        "event loop", "arrivals", "events", "wall s", "events/sec",
        "wall s per sim-hour", "peak open",
    ]
    reports = [
        {
            "title": "Sim-speed throughput: 100-replica fleet, short-decode Dynamic-Sonnet",
            "columns": cols,
            "rows": [
                [
                    "indexed + streamed", val(1_000_000, "count"),
                    val(12_000_000, "count"), val(2.4, "s"),
                    val(indexed_ev_s, "ev/s"), val(0.1, "s"), val(40, "count"),
                ],
                [
                    "scan oracle (eager)", val(100_000, "count"),
                    val(1_200_000, "count"), val(3.0, "s"),
                    val(oracle_ev_s, "ev/s"), val(1.25, "s"), val(100_000, "count"),
                ],
            ],
            "notes": [],
        },
    ]
    if with_macro:
        reports.append({
            "title": "Sim-speed macro-stepping throughput: 8-replica saturated decode-heavy drain",
            "columns": cols,
            "rows": [
                [
                    "macro bursts on", val(20_000, "count"),
                    val(5_200_000, "count"), val(1.0, "s"),
                    val(5.2e6, "ev/s"), val(0.2, "s"), val(20_000, "count"),
                ],
                [
                    "micro-step oracle", val(20_000, "count"),
                    val(5_200_000, "count"), val(2.1, "s"),
                    val(2.5e6, "ev/s"), val(0.4, "s"), val(20_000, "count"),
                ],
            ],
            "notes": [],
        })
    return {
        "schema": "cuda-myth/experiment-v1",
        "experiment": "sim_speed",
        "title": "synthetic sim-speed",
        "params": {"replicas": 100},
        "reports": reports,
        "expectations": [],
    }


def test_sim_speed_trend_across_commit_dirs(tmp_path):
    # One artifact directory per commit, oldest first: the trend figure
    # carries one line per event loop across both points.
    dirs = []
    for i, ev in enumerate([4.0e6, 5.5e6]):
        d = tmp_path / f"commit{i}"
        d.mkdir()
        (d / "BENCH_sim_speed.json").write_text(json.dumps(sim_speed_artifact(indexed_ev_s=ev)))
        dirs.append(str(d))
    out_dir = tmp_path / "plots"
    assert plot_bench.main([*dirs, "--out", str(out_dir)]) == 0
    trend = out_dir / "sim_speed__events-per-sec-trend.png"
    assert trend.exists(), sorted(out_dir.glob("*.png"))
    assert trend.stat().st_size > 1000


def test_sim_speed_single_dir_renders_trend_and_generic_curves(tmp_path):
    # The CI smoke shape: one directory still yields the trend figure
    # (single-point series), and "ev/s" is a curve unit so the generic
    # per-report figure renders alongside it.
    d = tmp_path / "bench"
    d.mkdir()
    (d / "BENCH_sim_speed.json").write_text(json.dumps(sim_speed_artifact()))
    out_dir = tmp_path / "plots"
    assert plot_bench.main([str(d), "--out", str(out_dir)]) == 0
    assert (out_dir / "sim_speed__events-per-sec-trend.png").exists()
    assert list(out_dir.glob("sim_speed__sim-speed-throughput*.png")), sorted(
        out_dir.glob("*.png")
    )


def test_sim_speed_throughput_rows_include_macro_series():
    # Without the macro report: just the indexed/scan pair. With it: the
    # macro/micro pair joins the series list under its own row labels.
    plain = plot_bench.sim_speed_throughput_rows(sim_speed_artifact())
    assert [loop for loop, _ in plain] == ["indexed + streamed", "scan oracle (eager)"]
    full = plot_bench.sim_speed_throughput_rows(sim_speed_artifact(with_macro=True))
    assert [loop for loop, _ in full] == [
        "indexed + streamed", "scan oracle (eager)", "macro bursts on", "micro-step oracle",
    ]
    assert dict(full)["macro bursts on"] == 5.2e6


def test_sim_speed_trend_pads_macro_series_across_old_artifacts(tmp_path):
    # Commit 0 predates macro-stepping (no macro report); commit 1 has
    # it. The trend must still render, padding the macro series with a
    # NaN for the older directory instead of crashing or misaligning.
    specs = [dict(with_macro=False), dict(with_macro=True)]
    dirs = []
    for i, kw in enumerate(specs):
        d = tmp_path / f"commit{i}"
        d.mkdir()
        (d / "BENCH_sim_speed.json").write_text(json.dumps(sim_speed_artifact(**kw)))
        dirs.append(str(d))
    out_dir = tmp_path / "plots"
    assert plot_bench.main([*dirs, "--out", str(out_dir)]) == 0
    trend = out_dir / "sim_speed__events-per-sec-trend.png"
    assert trend.exists(), sorted(out_dir.glob("*.png"))
    assert trend.stat().st_size > 1000


def test_no_trend_without_sim_speed_artifact(tmp_path):
    art_dir = tmp_path / "bench"
    art_dir.mkdir()
    (art_dir / "BENCH_cache_sweep.json").write_text(json.dumps(synthetic_artifact()))
    out_dir = tmp_path / "plots"
    assert plot_bench.main([str(art_dir), "--out", str(out_dir)]) == 0
    assert not (out_dir / "sim_speed__events-per-sec-trend.png").exists()


def chaos_artifact():
    def timeline(fleet):
        rows = []
        for sched, dip_at in [("crash r0@3s (1.5s down)", 6), ("straggler r1 x4 [2,6]s", 5)]:
            for i in range(12):
                goodput = 2.0 if abs(i - dip_at) > 1 else 0.6
                rows.append([sched, val(0.5 + i * 1.0, "s"), val(goodput, "req/s")])
        return {
            "title": f"Chaos goodput timeline [{fleet}]",
            "columns": ["schedule", "t", "goodput"],
            "rows": rows,
            "notes": [],
        }

    return {
        "schema": "cuda-myth/experiment-v1",
        "experiment": "chaos_sweep",
        "title": "synthetic chaos",
        "params": {"seed": 47},
        "reports": [
            timeline("homogeneous 3x gaudi2"),
            timeline("mixed gaudi2/a100"),
            {
                "title": "Chaos fault windows",
                "columns": ["schedule", "kind", "from", "until"],
                "rows": [
                    ["crash r0@3s (1.5s down)", "crash", val(3.0, "s"), val(4.5, "s")],
                    ["straggler r1 x4 [2,6]s", "straggler", val(2.0, "s"), val(6.0, "s")],
                    ["storm", "preempt_storm", val(4.0, "s"), val(4.0, "s")],
                ],
                "notes": [],
            },
            {
                "title": "Chaos-sweep derived claims",
                "columns": ["claim", "value"],
                "rows": [["conservation", val(0.0, "count")]],
                "notes": [],
            },
        ],
        "expectations": [],
    }


def test_chaos_fault_windows_parsed():
    windows = plot_bench.chaos_fault_windows(chaos_artifact())
    assert len(windows) == 3
    assert windows[0] == ("crash r0@3s (1.5s down)", "crash", 3.0, 4.5)
    assert windows[2][1] == "preempt_storm"


def test_chaos_artifact_gets_shaded_timeline_per_fleet(tmp_path):
    art_dir = tmp_path / "bench"
    art_dir.mkdir()
    (art_dir / "BENCH_chaos_sweep.json").write_text(json.dumps(chaos_artifact()))
    out_dir = tmp_path / "plots"
    assert plot_bench.main([str(art_dir), "--out", str(out_dir)]) == 0
    timelines = sorted(out_dir.glob("chaos_sweep__chaos-goodput-timeline*.png"))
    assert len(timelines) == 2, sorted(out_dir.glob("*.png"))
    for png in timelines:
        assert png.stat().st_size > 1000


def test_chaos_timeline_replaces_generic_rendering(tmp_path):
    # The timeline reports must be rendered exactly once (the dedicated
    # shaded figure), not additionally as generic per-report curves: two
    # timeline figures plus possibly the windows report's generic plot.
    art_dir = tmp_path / "bench"
    art_dir.mkdir()
    (art_dir / "BENCH_chaos_sweep.json").write_text(json.dumps(chaos_artifact()))
    out_dir = tmp_path / "plots"
    assert plot_bench.main([str(art_dir), "--out", str(out_dir)]) == 0
    names = [p.name for p in out_dir.glob("chaos_sweep__chaos-goodput-timeline*.png")]
    assert sorted(names) == sorted(set(names))


def test_chaos_timeline_without_windows_report_still_renders(tmp_path):
    art = chaos_artifact()
    art["reports"] = [r for r in art["reports"] if r["title"] != "Chaos fault windows"]
    art_dir = tmp_path / "bench"
    art_dir.mkdir()
    (art_dir / "BENCH_chaos_sweep.json").write_text(json.dumps(art))
    out_dir = tmp_path / "plots"
    assert plot_bench.main([str(art_dir), "--out", str(out_dir)]) == 0
    assert plot_bench.chaos_fault_windows(art) == []
    assert list(out_dir.glob("chaos_sweep__chaos-goodput-timeline*.png"))


def tp_sweep_artifact():
    def device_report(device, base_tps):
        rows = []
        for i, tp in enumerate([1, 2, 4, 8]):
            tps = base_tps * tp * (0.95 ** i)  # sub-linear measured curve
            rows.append([
                f"tp={tp}",
                val(141.0 / tp, "GB"),
                val(0 if tp == 1 else 40_000 * tp, "count"),
                val(0 if tp == 1 else 300 * tp, "count"),
                val(0 if tp == 1 else 1, "count"),
                val(tps, "tok/s"),
                val(tps / base_tps, "ratio"),
                val(tps / base_tps / tp, "ratio"),
                val(0.0 if tp == 1 else 0.05 * tp, "frac"),
            ])
        return {
            "title": f"TP sweep [{device}]: Llama-3.1-70B device-group sizing and scaling",
            "columns": [
                "group", "weights GB/card", "KV tokens", "KV blocks", "fits",
                "tok/s", "speedup", "scaling eff", "comm share",
            ],
            "rows": rows,
            "notes": [],
        }

    return {
        "schema": "cuda-myth/experiment-v1",
        "experiment": "tp_sweep",
        "title": "synthetic tp sweep",
        "params": {"seed": 31},
        "reports": [
            device_report("Gaudi-2", 500.0),
            device_report("A100", 400.0),
            {
                "title": "TP-sweep derived claims",
                "columns": ["claim", "value"],
                "rows": [["parity", val(0.0, "s")]],
                "notes": [],
            },
        ],
        "expectations": [],
    }


def test_tp_scaling_series_parsed():
    series = plot_bench.tp_scaling_series(tp_sweep_artifact())
    assert [device for device, _, _ in series] == ["Gaudi-2", "A100"]
    device, tps, ys = series[0]
    assert tps == [1, 2, 4, 8]
    assert ys[0] == 500.0 and ys[-1] > ys[0]


def test_tp_sweep_artifact_gets_scaling_figure(tmp_path):
    art_dir = tmp_path / "bench"
    art_dir.mkdir()
    (art_dir / "BENCH_tp_sweep.json").write_text(json.dumps(tp_sweep_artifact()))
    out_dir = tmp_path / "plots"
    assert plot_bench.main([str(art_dir), "--out", str(out_dir)]) == 0
    scaling = out_dir / "tp_sweep__tp-scaling.png"
    assert scaling.exists(), sorted(out_dir.glob("*.png"))
    assert scaling.stat().st_size > 1000
    # The per-device generic curves render alongside the combined figure.
    assert len(list(out_dir.glob("tp_sweep__tp-sweep*.png"))) >= 2


def test_no_scaling_figure_without_tp_reports(tmp_path):
    art_dir = tmp_path / "bench"
    art_dir.mkdir()
    (art_dir / "BENCH_cache_sweep.json").write_text(json.dumps(synthetic_artifact()))
    out_dir = tmp_path / "plots"
    assert plot_bench.main([str(art_dir), "--out", str(out_dir)]) == 0
    assert plot_bench.tp_scaling_series(synthetic_artifact()) == []
    assert not (out_dir / "cache_sweep__tp-scaling.png").exists()


def fleet_budget_artifact():
    shapes = ["8x tp1", "4x tp2", "2x tp4", "1x tp8"]
    per_card = {"Gaudi-2": [0.0, 0.42, 0.35, 0.22], "A100": [0.0, 0.38, 0.31, 0.2]}
    return {
        "schema": "cuda-myth/experiment-v1",
        "experiment": "fleet_budget",
        "title": "synthetic fleet budget",
        "params": {"seed": 47},
        "reports": [
            {
                "title": "Fleet-budget goodput frontier",
                "columns": ["shape", "Gaudi-2 goodput/card", "A100 goodput/card"],
                "rows": [
                    [shape, val(per_card["Gaudi-2"][i], "req/s"), val(per_card["A100"][i], "req/s")]
                    for i, shape in enumerate(shapes)
                ],
                "notes": [],
            },
            {
                "title": "Fleet-budget derived claims",
                "columns": ["claim", "value"],
                "rows": [["cards conserved", val(0.0, "count")]],
                "notes": [],
            },
        ],
        "expectations": [],
    }


def test_fleet_frontier_series_parsed():
    shapes, series = plot_bench.fleet_frontier_series(fleet_budget_artifact())
    assert shapes == ["8x tp1", "4x tp2", "2x tp4", "1x tp8"]
    assert [device for device, _ in series] == ["Gaudi-2", "A100"]
    device, ys = series[0]
    assert ys[0] == 0.0  # the infeasible tp=1 cliff
    assert ys[1] == max(ys)


def test_fleet_budget_artifact_gets_frontier_figure(tmp_path):
    art_dir = tmp_path / "bench"
    art_dir.mkdir()
    (art_dir / "BENCH_fleet_budget.json").write_text(json.dumps(fleet_budget_artifact()))
    out_dir = tmp_path / "plots"
    assert plot_bench.main([str(art_dir), "--out", str(out_dir)]) == 0
    frontier = out_dir / "fleet_budget__fleet-shape-frontier.png"
    assert frontier.exists(), sorted(out_dir.glob("*.png"))
    assert frontier.stat().st_size > 1000


def test_no_frontier_figure_without_fleet_report(tmp_path):
    art_dir = tmp_path / "bench"
    art_dir.mkdir()
    (art_dir / "BENCH_cache_sweep.json").write_text(json.dumps(synthetic_artifact()))
    out_dir = tmp_path / "plots"
    assert plot_bench.main([str(art_dir), "--out", str(out_dir)]) == 0
    assert plot_bench.fleet_frontier_series(synthetic_artifact()) == ([], [])
    assert not (out_dir / "cache_sweep__fleet-shape-frontier.png").exists()


def test_slugify():
    assert plot_bench.slugify("Fig 17(d): SLO knee / sweep") == "fig-17-d-slo-knee-sweep"
    assert plot_bench.slugify("***") == "report"
