"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py),
including hypothesis sweeps over shapes and dtypes — the core correctness
signal of the kernel layer."""

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic fixed-sample fallback
    from _hypothesis_fallback import given, settings, strategies as st

from compile.kernels import embedding_gather, paged_attention, ref, stream_ops

F_DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------- STREAM


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scalar=st.floats(min_value=-4, max_value=4, allow_nan=False),
    dtype_idx=st.integers(min_value=0, max_value=1),
)
def test_stream_ops_match_ref(n, seed, scalar, dtype_idx):
    dtype = F_DTYPES[dtype_idx]
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal(n), dtype)
    b = jnp.asarray(rng.standard_normal(n), dtype)
    np.testing.assert_allclose(
        np.asarray(stream_ops.add(a, b), np.float32),
        np.asarray(ref.add(a, b), np.float32), **_tol(dtype))
    np.testing.assert_allclose(
        np.asarray(stream_ops.scale(a, scalar), np.float32),
        np.asarray(ref.scale(a, scalar), np.float32), **_tol(dtype))
    np.testing.assert_allclose(
        np.asarray(stream_ops.triad(a, b, scalar), np.float32),
        np.asarray(ref.triad(a, b, scalar), np.float32), **_tol(dtype))


def test_stream_exact_tile_boundary():
    for n in [1024, 1023, 1025, 8 * 128]:
        a = jnp.arange(n, dtype=jnp.float32)
        b = jnp.ones(n, jnp.float32)
        np.testing.assert_allclose(stream_ops.add(a, b), np.arange(n) + 1.0)


# --------------------------------------------------------------- embedding


@settings(max_examples=20, deadline=None)
@given(
    n_tables=st.integers(min_value=1, max_value=5),
    batch_chunks=st.integers(min_value=1, max_value=6),
    dim=st.sampled_from([16, 64, 128]),
    rows=st.integers(min_value=8, max_value=200),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_batched_gather_matches_ref(n_tables, batch_chunks, dim, rows, seed):
    rng = np.random.default_rng(seed)
    batch = 4 * batch_chunks
    tables = jnp.asarray(rng.standard_normal((rows * n_tables, dim)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, rows, (n_tables, batch)), jnp.int32)
    offs = jnp.arange(n_tables, dtype=jnp.int32) * rows
    got = embedding_gather.batched_embedding_gather(tables, idx, offs)
    want = ref.batched_embedding_gather(tables, idx, offs)
    np.testing.assert_allclose(got, want)


def test_pooled_lookup_sums_over_pooling_axis():
    rng = np.random.default_rng(0)
    tables = jnp.asarray(rng.standard_normal((50, 32)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 25, (2, 4, 3)), jnp.int32)
    offs = jnp.array([0, 25], jnp.int32)
    got = embedding_gather.pooled_embedding_lookup(tables, idx, offs)
    flat = ref.batched_embedding_gather(tables, idx.reshape(2, 12), offs)
    want = flat.reshape(2, 4, 3, 32).sum(axis=2)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------- paged attention


@settings(max_examples=15, deadline=None)
@given(
    batch=st.integers(min_value=1, max_value=4),
    head_dim=st.sampled_from([16, 32, 64]),
    block_size=st.sampled_from([8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    data=st.data(),
)
def test_paged_attention_matches_ref(batch, head_dim, block_size, seed, data):
    rng = np.random.default_rng(seed)
    # Random CSR structure: 1..3 blocks per sequence, random physical ids.
    blocks_per = [data.draw(st.integers(1, 3)) for _ in range(batch)]
    num_blocks = sum(blocks_per) + 2
    block_ids, offsets = [], [0]
    perm = rng.permutation(num_blocks)
    k = 0
    for nb in blocks_per:
        block_ids.extend(perm[k:k + nb])
        k += nb
        offsets.append(len(block_ids))
    seq_lens = [
        data.draw(st.integers(1, nb * block_size)) for nb in blocks_per
    ]
    q = jnp.asarray(rng.standard_normal((batch, head_dim)), jnp.float32)
    kv = jnp.asarray(rng.standard_normal((2, num_blocks, block_size, head_dim)), jnp.float32)
    bl = jnp.asarray(block_ids, jnp.int32)
    off = jnp.asarray(offsets, jnp.int32)
    lens = jnp.asarray(seq_lens, jnp.int32)
    got = paged_attention.paged_attention(q, kv, bl, off, lens, block_size)
    want = ref.paged_attention(q, kv, bl, off, lens, block_size)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_paged_attention_masks_beyond_seq_len():
    # Poison tokens beyond seq_len with huge values: output must not change.
    rng = np.random.default_rng(1)
    bs, nb, d = 8, 2, 16
    q = jnp.asarray(rng.standard_normal((1, d)), jnp.float32)
    kv = jnp.asarray(rng.standard_normal((2, nb, bs, d)), jnp.float32)
    bl = jnp.array([0, 1], jnp.int32)
    off = jnp.array([0, 2], jnp.int32)
    lens = jnp.array([10], jnp.int32)
    base = paged_attention.paged_attention(q, kv, bl, off, lens, bs)
    poisoned = kv.at[:, 1, 3:, :].set(1e6)  # positions 11.. (beyond len 10)
    got = paged_attention.paged_attention(q, poisoned, bl, off, lens, bs)
    np.testing.assert_allclose(got, base, rtol=1e-6, atol=1e-6)


def test_paged_attention_multihead_shape():
    rng = np.random.default_rng(2)
    heads, batch, d, bs, nb = 3, 2, 16, 8, 4
    q = jnp.asarray(rng.standard_normal((heads, batch, d)), jnp.float32)
    kv = jnp.asarray(rng.standard_normal((heads, 2, nb, bs, d)), jnp.float32)
    bl = jnp.array([0, 1, 2, 3], jnp.int32)
    off = jnp.array([0, 2, 4], jnp.int32)
    lens = jnp.array([12, 9], jnp.int32)
    out = paged_attention.paged_attention_multihead(q, kv, bl, off, lens, bs)
    assert out.shape == (heads, batch, d)
    # Head 0 must equal the single-head kernel on its slice.
    want = paged_attention.paged_attention(q[0], kv[0], bl, off, lens, bs)
    np.testing.assert_allclose(out[0], want, rtol=1e-6, atol=1e-6)


# ------------------------------------------------------------ flash prefill

from compile.kernels import flash_prefill


@settings(max_examples=15, deadline=None)
@given(
    seq_blocks=st.integers(min_value=1, max_value=6),
    head_dim=st.sampled_from([16, 32, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_flash_prefill_matches_causal_ref(seq_blocks, head_dim, seed):
    rng = np.random.default_rng(seed)
    seq = 16 * seq_blocks
    q = jnp.asarray(rng.standard_normal((seq, head_dim)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((seq, head_dim)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((seq, head_dim)), jnp.float32)
    got = flash_prefill.flash_prefill(q, k, v)
    want = ref.causal_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_flash_prefill_is_causal():
    # Poisoning FUTURE keys/values must not change earlier outputs.
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    base = flash_prefill.flash_prefill(q, k, v)
    k2 = k.at[20:].set(1e3)
    v2 = v.at[20:].set(1e3)
    got = flash_prefill.flash_prefill(q, k2, v2)
    np.testing.assert_allclose(got[:20], base[:20], rtol=1e-6, atol=1e-6)


def test_flash_prefill_multihead_shape():
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.float32)
    out = flash_prefill.flash_prefill_multihead(q, k, v)
    assert out.shape == (2, 16, 32)
    np.testing.assert_allclose(
        out[1], flash_prefill.flash_prefill(q[1], k[1], v[1]), rtol=1e-6, atol=1e-6)
