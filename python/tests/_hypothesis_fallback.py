"""Deterministic fallback for `hypothesis` when it isn't installed (the
offline container has no package index). Implements just the surface
`test_kernels.py` uses — `given`, `settings`, and the `integers`,
`floats`, `sampled_from`, `data` strategies — drawing a small fixed
number of seeded examples per test instead of hypothesis' adaptive
search. No shrinking: a failure reports the concrete kwargs drawn.
"""

import numpy as np

# Keep runtime bounded: Pallas interpret-mode kernels are slow.
_MAX_EXAMPLES = 5


class _Strategy:
    def __init__(self, sampler):
        self._sampler = sampler

    def sample(self, rng):
        return self._sampler(rng)


class _Data:
    """Mimics hypothesis' interactive `data()` object."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy):
        return strategy.sample(self._rng)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value, allow_nan=False, **_kw):
        del allow_nan  # uniform draws are never NaN
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(0, len(opts)))])

    @staticmethod
    def data():
        return _Strategy(_Data)


def settings(max_examples=_MAX_EXAMPLES, deadline=None, **_kw):
    del deadline

    def deco(fn):
        fn._fallback_max_examples = min(max_examples, _MAX_EXAMPLES)
        return fn

    return deco


def given(**strats):
    def deco(fn):
        # NOTE: no functools.wraps — it would copy `fn`'s signature and
        # make pytest treat the strategy kwargs as fixtures.
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples", _MAX_EXAMPLES)
            for case in range(n):
                rng = np.random.default_rng(0xC0FFEE + 7919 * case)
                kwargs = {name: s.sample(rng) for name, s in strats.items()}
                try:
                    fn(**kwargs)
                except Exception:
                    print(f"fallback-given case {case}: kwargs = {kwargs!r}")
                    raise

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
