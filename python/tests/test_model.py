"""L2 correctness: the tiny-Llama decoder and tiny-DLRM forward — shape
contracts, prefill/decode consistency, causality, and KV-cache slot
isolation."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model

CFG = model.TinyLlamaConfig()
DCFG = model.TinyDlrmConfig()


@pytest.fixture(scope="module")
def weights():
    return model.init_llama_weights(CFG)


@pytest.fixture(scope="module")
def dlrm_weights():
    return model.init_dlrm_weights(DCFG)


def zero_kv():
    return jnp.zeros(
        (CFG.layers, 2, CFG.batch, CFG.n_kv_heads, CFG.max_seq, CFG.head_dim),
        jnp.float32,
    )


def test_weight_packing_roundtrip(weights):
    shapes = model.llama_weight_shapes(CFG)
    assert weights.shape == (model.llama_num_weights(CFG),)
    w = model.unpack_weights(weights, shapes)
    assert w["embed"].shape == (CFG.vocab, CFG.hidden)
    assert w["l0.wq"].shape == (CFG.hidden, CFG.n_q_heads * CFG.head_dim)


def test_decode_step_shapes(weights):
    toks = jnp.array([1, 2, 3, 4], jnp.int32)
    logits, kv = model.decode_step(weights, toks, zero_kv(), jnp.zeros(4, jnp.int32), CFG)
    assert logits.shape == (CFG.batch, CFG.vocab)
    assert kv.shape == zero_kv().shape
    assert bool(jnp.isfinite(logits).all())


def test_prefill_matches_stepwise_decode(weights):
    """Prefill of a prompt must produce the same last-position logits and
    KV state as feeding the prompt token by token through decode_step."""
    prompt = jnp.array([7, 3, 9, 1, 30], jnp.int32)
    n = len(prompt)
    padded = jnp.zeros(CFG.prompt_pad, jnp.int32).at[:n].set(prompt)
    lg_pre, kv_pre = model.prefill(
        weights, padded, zero_kv(), jnp.array([2], jnp.int32), jnp.array([n], jnp.int32), CFG)
    kv = zero_kv()
    pos = jnp.zeros(CFG.batch, jnp.int32)
    for t in range(n):
        toks = jnp.zeros(CFG.batch, jnp.int32).at[2].set(prompt[t])
        lg_dec, kv = model.decode_step(weights, toks, kv, pos, CFG)
        pos = pos.at[2].add(1)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(lg_dec[2]), rtol=3e-4, atol=3e-4)
    # KV of slot 2 over the prompt span must agree too.
    np.testing.assert_allclose(
        np.asarray(kv_pre[:, :, 2, :, :n]), np.asarray(kv[:, :, 2, :, :n]),
        rtol=3e-4, atol=3e-4)


def test_slots_are_isolated(weights):
    """Writing a prompt into slot 0 must not disturb slot 3's KV."""
    kv0 = zero_kv()
    marker = kv0.at[:, :, 3].set(42.0)
    padded = jnp.zeros(CFG.prompt_pad, jnp.int32).at[:4].set(jnp.array([5, 6, 7, 8]))
    _, kv1 = model.prefill(
        weights, padded, marker, jnp.array([0], jnp.int32), jnp.array([4], jnp.int32), CFG)
    np.testing.assert_array_equal(np.asarray(kv1[:, :, 3]), 42.0)
    assert float(jnp.abs(kv1[:, :, 0, :, :4]).sum()) > 0.0


def test_decode_attends_to_history(weights):
    """The same token must produce different logits under different
    histories (the KV cache is actually consulted)."""
    padded_a = jnp.zeros(CFG.prompt_pad, jnp.int32).at[:3].set(jnp.array([1, 2, 3]))
    padded_b = jnp.zeros(CFG.prompt_pad, jnp.int32).at[:3].set(jnp.array([9, 8, 7]))
    slot = jnp.array([0], jnp.int32)
    n = jnp.array([3], jnp.int32)
    _, kv_a = model.prefill(weights, padded_a, zero_kv(), slot, n, CFG)
    _, kv_b = model.prefill(weights, padded_b, zero_kv(), slot, n, CFG)
    toks = jnp.zeros(CFG.batch, jnp.int32).at[0].set(4)
    pos = jnp.zeros(CFG.batch, jnp.int32).at[0].set(3)
    lg_a, _ = model.decode_step(weights, toks, kv_a, pos, CFG)
    lg_b, _ = model.decode_step(weights, toks, kv_b, pos, CFG)
    assert float(jnp.abs(lg_a[0] - lg_b[0]).max()) > 1e-3


def test_prefill_padding_is_ignored(weights):
    """Junk beyond `length` must not affect the last-position logits."""
    base = jnp.zeros(CFG.prompt_pad, jnp.int32).at[:3].set(jnp.array([1, 2, 3]))
    junk = base.at[3:].set(499)
    slot = jnp.array([1], jnp.int32)
    n = jnp.array([3], jnp.int32)
    lg1, _ = model.prefill(weights, base, zero_kv(), slot, n, CFG)
    lg2, _ = model.prefill(weights, junk, zero_kv(), slot, n, CFG)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), rtol=1e-6, atol=1e-6)


def test_dlrm_forward_shapes_and_sensitivity(dlrm_weights):
    rng = np.random.default_rng(0)
    dense = jnp.asarray(rng.standard_normal((DCFG.batch, DCFG.dense_in)), jnp.float32)
    idx = jnp.asarray(
        rng.integers(0, DCFG.rows_per_table, (DCFG.tables, DCFG.batch, DCFG.pooling)),
        jnp.int32)
    out = model.dlrm_forward(dlrm_weights, dense, idx, DCFG)
    assert out.shape == (DCFG.batch, 1)
    assert bool(jnp.isfinite(out).all())
    # Sensitivity to embedding indices.
    idx2 = (idx + 17) % DCFG.rows_per_table
    out2 = model.dlrm_forward(dlrm_weights, dense, idx2, DCFG)
    assert float(jnp.abs(out - out2).max()) > 1e-6
    # Sensitivity to dense features.
    out3 = model.dlrm_forward(dlrm_weights, dense + 1.0, idx, DCFG)
    assert float(jnp.abs(out - out3).max()) > 1e-6


def test_dlrm_weight_count(dlrm_weights):
    assert dlrm_weights.shape == (model.dlrm_num_weights(DCFG),)
