"""L2 JAX models, calling the L1 Pallas kernels.

Two build-time models are lowered to HLO artifacts:

* **Tiny-Llama decoder** — the same architecture family as the paper's
  Llama-3.1 workloads (RMSNorm, RoPE, GQA attention, SwiGLU MLP), sized to
  run fast on the CPU PJRT client. Decode attention goes through the
  `paged_attention` Pallas kernel: the contiguous per-slot KV cache is
  viewed as one KV block per sequence (block_size = max_seq, identity
  BlockList), so the serving path exercises the real kernel.
* **Tiny-DLRM** — embedding bags via the `pooled_embedding_lookup` Pallas
  kernel + bottom/top MLPs + dot interaction, for the RecSys example.

Weights travel as one flat f32 vector (packing order defined by
`*_weight_shapes`), so the Rust side never needs to understand the
pytree.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp

from compile.kernels import embedding_gather, flash_prefill, paged_attention


# ---------------------------------------------------------------- tiny llama


@dataclasses.dataclass(frozen=True)
class TinyLlamaConfig:
    vocab: int = 512
    hidden: int = 256
    layers: int = 2
    n_q_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 64
    intermediate: int = 512
    max_seq: int = 128
    batch: int = 4          # serving slots (static shape)
    prompt_pad: int = 32    # prefill artifact prompt padding
    rope_theta: float = 10000.0


def llama_weight_shapes(cfg: TinyLlamaConfig):
    """Ordered (name, shape) list defining the flat weight packing."""
    h, q, kv = cfg.hidden, cfg.n_q_heads * cfg.head_dim, cfg.n_kv_heads * cfg.head_dim
    shapes = [("embed", (cfg.vocab, h))]
    for l in range(cfg.layers):
        shapes += [
            (f"l{l}.norm1", (h,)),
            (f"l{l}.wq", (h, q)),
            (f"l{l}.wk", (h, kv)),
            (f"l{l}.wv", (h, kv)),
            (f"l{l}.wo", (q, h)),
            (f"l{l}.norm2", (h,)),
            (f"l{l}.wgate", (h, cfg.intermediate)),
            (f"l{l}.wup", (h, cfg.intermediate)),
            (f"l{l}.wdown", (cfg.intermediate, h)),
        ]
    shapes += [("norm_f", (h,))]
    return shapes


def llama_num_weights(cfg: TinyLlamaConfig) -> int:
    return sum(math.prod(s) for _, s in llama_weight_shapes(cfg))


def unpack_weights(flat, shapes):
    out = {}
    i = 0
    for name, shape in shapes:
        n = math.prod(shape)
        out[name] = flat[i : i + n].reshape(shape)
        i += n
    assert i == flat.shape[0]
    return out


def init_llama_weights(cfg: TinyLlamaConfig, seed: int = 0):
    """Deterministic random init, returned flat (an AOT artifact of its
    own so the Rust side never constructs weights)."""
    key = jax.random.PRNGKey(seed)
    parts = []
    for name, shape in llama_weight_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("norm1", "norm2")) or name == "norm_f":
            parts.append(jnp.ones(shape, jnp.float32).reshape(-1))
        else:
            scale = 1.0 / math.sqrt(shape[0])
            parts.append((jax.random.normal(sub, shape, jnp.float32) * scale).reshape(-1))
    return jnp.concatenate(parts)


def _rmsnorm(x, w):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * w


def _rope(x, pos, theta):
    """Rotary embedding. x: [..., heads, head_dim]; pos: broadcastable."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = pos[..., None, None] * freqs  # [..., 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _kv_shape(cfg: TinyLlamaConfig):
    return (cfg.layers, 2, cfg.batch, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim)


def kv_num_elements(cfg: TinyLlamaConfig) -> int:
    return math.prod(_kv_shape(cfg))


def _attend_decode(q, kv_layer, pos, cfg):
    """Decode attention via the paged-attention Pallas kernel.

    q: [batch, n_q_heads, head_dim]; kv_layer: [2, batch, n_kv_heads,
    max_seq, head_dim]; pos: [batch] current position (tokens already in
    KV *including* the one just written).
    """
    b = cfg.batch
    group = cfg.n_q_heads // cfg.n_kv_heads
    # View each sequence as ONE KV block: [2, B, S, D] per kv head.
    # block_list = identity, offsets = 0..B, seq_lens = pos.
    block_list = jnp.arange(b, dtype=jnp.int32)
    offsets = jnp.arange(b + 1, dtype=jnp.int32)
    outs = []
    for h in range(cfg.n_q_heads):
        kvh = h // group
        kv_cache = kv_layer[:, :, kvh]  # [2, B(blocks), S(block), D]
        out = paged_attention.paged_attention(
            q[:, h], kv_cache, block_list, offsets, pos, cfg.max_seq
        )
        outs.append(out)
    return jnp.stack(outs, axis=1)  # [B, heads, D]


def decode_step(flat_weights, tokens, kv, pos, cfg: TinyLlamaConfig):
    """One decode step for all slots.

    Args:
      flat_weights: [num_weights] f32.
      tokens: [batch] i32 current token per slot.
      kv: [layers, 2, batch, n_kv_heads, max_seq, head_dim] f32.
      pos: [batch] i32 position to write (tokens already cached).

    Returns:
      (logits [batch, vocab], updated kv).
    """
    w = unpack_weights(flat_weights, llama_weight_shapes(cfg))
    x = w["embed"][tokens]  # [B, h]
    b = cfg.batch
    posf = pos.astype(jnp.float32)
    for l in range(cfg.layers):
        h_in = _rmsnorm(x, w[f"l{l}.norm1"])
        q = (h_in @ w[f"l{l}.wq"]).reshape(b, cfg.n_q_heads, cfg.head_dim)
        k = (h_in @ w[f"l{l}.wk"]).reshape(b, cfg.n_kv_heads, cfg.head_dim)
        v = (h_in @ w[f"l{l}.wv"]).reshape(b, cfg.n_kv_heads, cfg.head_dim)
        q = _rope(q, posf, cfg.rope_theta)
        k = _rope(k, posf, cfg.rope_theta)
        # Write k, v at pos for each slot.
        for arr, which in ((k, 0), (v, 1)):
            def write_one(slot_kv, arr_b, p):
                # slot_kv: [n_kv, S, D]; arr_b: [n_kv, D]
                return jax.lax.dynamic_update_slice(
                    slot_kv, arr_b[:, None, :], (0, p, 0)
                )
            updated = jax.vmap(write_one)(kv[l, which], arr, pos)
            kv = kv.at[l, which].set(updated)
        attn = _attend_decode(q, kv[l], pos + 1, cfg)  # [B, heads, D]
        attn = attn.reshape(b, -1) @ w[f"l{l}.wo"]
        x = x + attn
        h2 = _rmsnorm(x, w[f"l{l}.norm2"])
        gate = jax.nn.silu(h2 @ w[f"l{l}.wgate"])
        up = h2 @ w[f"l{l}.wup"]
        x = x + (gate * up) @ w[f"l{l}.wdown"]
    x = _rmsnorm(x, w["norm_f"])
    logits = x @ w["embed"].T  # tied embedding
    return logits, kv


def prefill(flat_weights, tokens, kv, slot, length, cfg: TinyLlamaConfig):
    """Process a (padded) prompt into slot `slot`'s KV cache.

    Args:
      tokens: [prompt_pad] i32 (padded with anything beyond `length`).
      slot: [1] i32 slot index.
      length: [1] i32 true prompt length.

    Returns:
      (logits [vocab] at the last prompt position, updated kv).
    """
    w = unpack_weights(flat_weights, llama_weight_shapes(cfg))
    s = slot[0]
    n = length[0]
    x = w["embed"][tokens]  # [P, h]
    posf = jnp.arange(cfg.prompt_pad, dtype=jnp.float32)
    for l in range(cfg.layers):
        h_in = _rmsnorm(x, w[f"l{l}.norm1"])
        q = (h_in @ w[f"l{l}.wq"]).reshape(cfg.prompt_pad, cfg.n_q_heads, cfg.head_dim)
        k = (h_in @ w[f"l{l}.wk"]).reshape(cfg.prompt_pad, cfg.n_kv_heads, cfg.head_dim)
        v = (h_in @ w[f"l{l}.wv"]).reshape(cfg.prompt_pad, cfg.n_kv_heads, cfg.head_dim)
        q = _rope(q, posf, cfg.rope_theta)
        k = _rope(k, posf, cfg.rope_theta)
        # Causal attention within the prompt via the flash-prefill
        # Pallas kernel (one K/V pass, online softmax).
        rep = cfg.n_q_heads // cfg.n_kv_heads
        attn = flash_prefill.flash_prefill_multihead(
            q.transpose(1, 0, 2),
            jnp.repeat(k, rep, 1).transpose(1, 0, 2),
            jnp.repeat(v, rep, 1).transpose(1, 0, 2),
        ).transpose(1, 0, 2)
        x = x + attn.reshape(cfg.prompt_pad, -1) @ w[f"l{l}.wo"]
        h2 = _rmsnorm(x, w[f"l{l}.norm2"])
        gate = jax.nn.silu(h2 @ w[f"l{l}.wgate"])
        up = h2 @ w[f"l{l}.wup"]
        x = x + (gate * up) @ w[f"l{l}.wdown"]
        # Write the prompt's K/V into the slot (positions 0..P-1; junk
        # beyond `length` is never attended and later overwritten).
        kv = jax.lax.dynamic_update_slice(
            kv, k.transpose(1, 0, 2)[None, None, None], (l, 0, s, 0, 0, 0)
        )
        kv = jax.lax.dynamic_update_slice(
            kv, v.transpose(1, 0, 2)[None, None, None], (l, 1, s, 0, 0, 0)
        )
    x = _rmsnorm(x, w["norm_f"])
    logits = x @ w["embed"].T  # [P, vocab]
    last = jax.lax.dynamic_index_in_dim(logits, n - 1, axis=0, keepdims=False)
    return last, kv


# ------------------------------------------------------------------ tiny dlrm


@dataclasses.dataclass(frozen=True)
class TinyDlrmConfig:
    tables: int = 4
    rows_per_table: int = 1000
    emb_dim: int = 64
    dense_in: int = 13
    pooling: int = 4
    batch: int = 32
    bottom: tuple = (13, 64, 64)
    top: tuple = (64 + 4 * 64, 64, 1)


def dlrm_weight_shapes(cfg: TinyDlrmConfig):
    shapes = [("tables", (cfg.tables * cfg.rows_per_table, cfg.emb_dim))]
    for i in range(len(cfg.bottom) - 1):
        shapes += [(f"bot{i}.w", (cfg.bottom[i], cfg.bottom[i + 1])), (f"bot{i}.b", (cfg.bottom[i + 1],))]
    for i in range(len(cfg.top) - 1):
        shapes += [(f"top{i}.w", (cfg.top[i], cfg.top[i + 1])), (f"top{i}.b", (cfg.top[i + 1],))]
    return shapes


def dlrm_num_weights(cfg: TinyDlrmConfig) -> int:
    return sum(math.prod(s) for _, s in dlrm_weight_shapes(cfg))


def init_dlrm_weights(cfg: TinyDlrmConfig, seed: int = 1):
    key = jax.random.PRNGKey(seed)
    parts = []
    for name, shape in dlrm_weight_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".b"):
            parts.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        else:
            scale = 1.0 / math.sqrt(shape[0])
            parts.append((jax.random.normal(sub, shape, jnp.float32) * scale).reshape(-1))
    return jnp.concatenate(parts)


def dlrm_forward(flat_weights, dense, indices, cfg: TinyDlrmConfig):
    """DLRM forward: embedding bags (Pallas kernel) + MLPs + interaction.

    Args:
      dense: [batch, dense_in] f32 dense features.
      indices: [tables, batch, pooling] i32 table-local row ids.

    Returns:
      [batch, 1] click-probability logits.
    """
    w = unpack_weights(flat_weights, dlrm_weight_shapes(cfg))
    offsets = jnp.arange(cfg.tables, dtype=jnp.int32) * cfg.rows_per_table
    pooled = embedding_gather.pooled_embedding_lookup(w["tables"], indices, offsets)
    # pooled: [tables, batch, emb_dim] -> [batch, tables*emb_dim]
    emb = pooled.transpose(1, 0, 2).reshape(cfg.batch, -1)
    x = dense
    for i in range(len(cfg.bottom) - 1):
        x = jax.nn.relu(x @ w[f"bot{i}.w"] + w[f"bot{i}.b"])
    x = jnp.concatenate([x, emb], axis=1)
    for i in range(len(cfg.top) - 1):
        x = x @ w[f"top{i}.w"] + w[f"top{i}.b"]
        if i < len(cfg.top) - 2:
            x = jax.nn.relu(x)
    return x
