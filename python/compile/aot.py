"""AOT lowering: JAX/Pallas (L2+L1) -> HLO text artifacts + manifest.json.

Interchange is HLO *text*, not `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via `make artifacts`. Python never runs on the request path: the Rust
coordinator loads these files through PJRT and serves from them.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import embedding_gather, flash_prefill, paged_attention, stream_ops

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def tensor_json(s):
    name = {jnp.float32: "float32", jnp.int32: "int32"}[
        {"float32": jnp.float32, "int32": jnp.int32}[str(s.dtype)]
    ]
    return {"shape": list(s.shape), "dtype": name}


def entry(name, fn, in_specs, meta=None):
    """Lower `fn` at `in_specs`, return (manifest entry, hlo text)."""
    lowered = jax.jit(fn).lower(*in_specs)
    text = to_hlo_text(lowered)
    out_shapes = jax.eval_shape(fn, *in_specs)
    if not isinstance(out_shapes, (tuple, list)):
        out_shapes = (out_shapes,)
    ent = {
        "name": name,
        "file": f"{name}.hlo.txt",
        "inputs": [tensor_json(s) for s in in_specs],
        "outputs": [tensor_json(s) for s in out_shapes],
        "meta": meta or {},
    }
    return ent, text


def build_entries():
    cfg = model.TinyLlamaConfig()
    dcfg = model.TinyDlrmConfig()
    nw = model.llama_num_weights(cfg)
    kv_shape = (cfg.layers, 2, cfg.batch, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim)
    llama_meta = {
        "batch": cfg.batch,
        "max_seq": cfg.max_seq,
        "prompt_pad": cfg.prompt_pad,
        "vocab": cfg.vocab,
        "layers": cfg.layers,
        "hidden": cfg.hidden,
        "num_weights": nw,
    }
    entries = []

    # --- tiny-llama serving artifacts --------------------------------
    entries.append(entry(
        "init_llama_weights", lambda: (model.init_llama_weights(cfg),), [], llama_meta))
    entries.append(entry(
        "prefill",
        lambda w, t, kv, s, n: model.prefill(w, t, kv, s, n, cfg),
        [spec((nw,), F32), spec((cfg.prompt_pad,), I32), spec(kv_shape, F32),
         spec((1,), I32), spec((1,), I32)],
        llama_meta,
    ))
    entries.append(entry(
        "decode_step",
        lambda w, t, kv, p: model.decode_step(w, t, kv, p, cfg),
        [spec((nw,), F32), spec((cfg.batch,), I32), spec(kv_shape, F32),
         spec((cfg.batch,), I32)],
        llama_meta,
    ))

    # --- tiny-dlrm artifacts -----------------------------------------
    dnw = model.dlrm_num_weights(dcfg)
    dlrm_meta = {
        "batch": dcfg.batch, "tables": dcfg.tables, "pooling": dcfg.pooling,
        "rows_per_table": dcfg.rows_per_table, "emb_dim": dcfg.emb_dim,
        "dense_in": dcfg.dense_in, "num_weights": dnw,
    }
    entries.append(entry(
        "init_dlrm_weights", lambda: (model.init_dlrm_weights(dcfg),), [], dlrm_meta))
    entries.append(entry(
        "dlrm_forward",
        lambda w, d, i: (model.dlrm_forward(w, d, i, dcfg),),
        [spec((dnw,), F32), spec((dcfg.batch, dcfg.dense_in), F32),
         spec((dcfg.tables, dcfg.batch, dcfg.pooling), I32)],
        dlrm_meta,
    ))

    # --- standalone kernel artifacts (validated from Rust) -----------
    n = 65536
    entries.append(entry(
        "stream_triad",
        lambda a, b: (stream_ops.triad(a, b, 3.0),),
        [spec((n,), F32), spec((n,), F32)],
        {"n": n, "scalar": 3},
    ))
    entries.append(entry(
        "embedding_gather",
        lambda t, i, o: (embedding_gather.batched_embedding_gather(t, i, o),),
        [spec((256, 128), F32), spec((4, 16), I32), spec((4,), I32)],
        {"tables": 4, "batch": 16, "dim": 128},
    ))
    fseq, fd = 64, 64
    entries.append(entry(
        "flash_prefill",
        lambda q, k, v: (flash_prefill.flash_prefill(q, k, v),),
        [spec((fseq, fd), F32), spec((fseq, fd), F32), spec((fseq, fd), F32)],
        {"seq": fseq, "head_dim": fd},
    ))
    bs, nb, d, batch = 16, 8, 64, 4
    entries.append(entry(
        "paged_attention",
        lambda q, kv, bl, off, lens: (
            paged_attention.paged_attention(q, kv, bl, off, lens, bs),),
        [spec((batch, d), F32), spec((2, nb, bs, d), F32), spec((nb,), I32),
         spec((batch + 1,), I32), spec((batch,), I32)],
        {"batch": batch, "num_blocks": nb, "block_size": bs, "head_dim": d},
    ))
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"entries": []}
    for ent, text in build_entries():
        path = os.path.join(args.out_dir, ent["file"])
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(ent)
        print(f"wrote {path} ({len(text)/1e6:.2f} MB)")
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath} ({len(manifest['entries'])} entries)")


if __name__ == "__main__":
    main()
