"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness contracts: pytest (+hypothesis shape/dtype
sweeps) asserts `kernels.* == ref.*` under `assert_allclose`, which is the
core correctness signal of the L1 layer.
"""

import jax.numpy as jnp


def add(a, b):
    """STREAM ADD: c[i] = a[i] + b[i] (paper Algorithm 1)."""
    return a + b


def scale(a, scalar):
    """STREAM SCALE: b[i] = scalar * a[i]."""
    return scalar * a


def triad(a, b, scalar):
    """STREAM TRIAD: c[i] = scalar * a[i] + b[i]."""
    return scalar * a + b


def batched_embedding_gather(tables, indices, table_offsets):
    """FBGEMM-style BatchedTable lookup (paper Fig 14(b)).

    Args:
      tables: [total_rows, dim] -- all embedding tables stacked row-wise.
      indices: [n_tables, batch] -- per-table row indices (table-local).
      table_offsets: [n_tables] -- starting row of each table within
        `tables` (the BatchedTable trick: one big table + offsets).

    Returns:
      [n_tables, batch, dim] gathered embedding vectors.
    """
    flat = indices + table_offsets[:, None]  # [n_tables, batch] global rows
    return tables[flat]


def paged_attention(q, kv_cache, block_list, block_offsets, seq_lens, block_size):
    """BlockList-form paged attention for one decode step (Fig 16(b)).

    Single-head reference semantics (callers vmap over heads): for each
    query i, attend over its `seq_lens[i]` cached tokens, whose KV lives in
    the physical blocks `block_list[block_offsets[i] : block_offsets[i+1]]`.

    Args:
      q: [batch, head_dim] query vectors.
      kv_cache: [2, num_blocks, block_size, head_dim] paged K and V.
      block_list: [total_blocks] physical block ids (BlockList layout).
      block_offsets: [batch+1] CSR row offsets into block_list.
      seq_lens: [batch] effectual KV length per sequence.
      block_size: tokens per block.

    Returns:
      [batch, head_dim] attention outputs (float32).
    """
    del block_size
    batch, head_dim = q.shape
    outs = []
    for i in range(batch):
        lo, hi = int(block_offsets[i]), int(block_offsets[i + 1])
        blocks = block_list[lo:hi]
        k = kv_cache[0, blocks].reshape(-1, head_dim)  # [nb*bs, d]
        v = kv_cache[1, blocks].reshape(-1, head_dim)
        n = int(seq_lens[i])
        scores = (k[:n].astype(jnp.float32) @ q[i].astype(jnp.float32)) / jnp.sqrt(
            jnp.float32(head_dim)
        )
        p = jnp.exp(scores - scores.max())
        p = p / p.sum()
        outs.append(p @ v[:n].astype(jnp.float32))
    return jnp.stack(outs)


def causal_attention(q, k, v):
    """Causal (prefill) attention reference, single head: [seq, d]."""
    import jax.numpy as jnp
    seq, d = q.shape
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) / jnp.sqrt(jnp.float32(d))
    mask = jnp.tril(jnp.ones((seq, seq), bool))
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(axis=1, keepdims=True))
    p = p / p.sum(axis=1, keepdims=True)
    return p @ v.astype(jnp.float32)
