"""BlockList paged attention as a Pallas kernel (paper §4.2, Fig 16(b)).

This is the vLLM_opt form: a flat list of *effectual* KV-block ids with
CSR offsets per sequence — no zero-padding work. Each grid program owns
one (sequence, head-group) pair and runs a flash-style online softmax over
that sequence's blocks:

  for each block j of sequence i:
      k, v = KV[block_list[offsets[i]+j]]         (TPC gather)
      s    = k @ q / sqrt(d)                      (MME batched GEMM)
      online-softmax accumulate                   (TPC vector ops)

which is exactly the gather→bgemm→softmax slicing the Gaudi graph
compiler pipelines across TPC and MME (and the structure a real TPU
lowering would tile through VMEM with the MXU doing `k @ q`).

interpret=True: see stream_ops.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_BIG = -1e30


def _paged_attn_kernel(
    q_ref,
    kv_ref,
    bl_ref,
    off_ref,
    len_ref,
    o_ref,
    *,
    block_size,
    max_blocks_per_seq,
):
    i = pl.program_id(0)
    q = q_ref[0, :].astype(jnp.float32)  # [d]
    d = q.shape[0]
    lo = off_ref[i]
    n_blocks = off_ref[i + 1] - lo
    seq_len = len_ref[i]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    def body(j, carry):
        m, l, acc = carry
        valid = j < n_blocks
        # Clamp so the load stays in bounds even for invalid iterations.
        slot = jnp.where(valid, lo + j, lo)
        blk = bl_ref[slot]
        # jax >= 0.4.37 rejects bare int indices in pl.load (they reach the
        # NDIndexer as shapeless Python ints); use length-1 dslices instead.
        k = pl.load(kv_ref, (pl.dslice(0, 1), pl.dslice(blk, 1), slice(None), slice(None)))[0, 0]
        v = pl.load(kv_ref, (pl.dslice(1, 1), pl.dslice(blk, 1), slice(None), slice(None)))[0, 0]
        s = (k.astype(jnp.float32) @ q) * scale  # [block_size]
        pos = j * block_size + jax.lax.iota(jnp.int32, block_size)
        mask = (pos < seq_len) & valid
        s = jnp.where(mask, s, _NEG_BIG)
        m_new = jnp.maximum(m, s.max())
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l_new = l * alpha + p.sum()
        acc_new = acc * alpha + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.float32(_NEG_BIG)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d,), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, max_blocks_per_seq, body, (m0, l0, acc0))
    o_ref[0, :] = acc / jnp.maximum(l, 1e-30)


def paged_attention(q, kv_cache, block_list, block_offsets, seq_lens, block_size):
    """BlockList paged attention, one decode step, single head.

    Args:
      q: [batch, head_dim].
      kv_cache: [2, num_blocks, block_size, head_dim].
      block_list: [total_blocks] int32 physical block ids.
      block_offsets: [batch+1] int32 CSR offsets.
      seq_lens: [batch] int32 effectual lengths.
      block_size: static tokens/block (must equal kv_cache.shape[2]).

    Returns:
      [batch, head_dim] float32 outputs.
    """
    batch, head_dim = q.shape
    assert kv_cache.shape[2] == block_size
    # Static upper bound on blocks per sequence.
    max_blocks_per_seq = int(kv_cache.shape[1])
    kernel = functools.partial(
        _paged_attn_kernel,
        block_size=block_size,
        max_blocks_per_seq=max_blocks_per_seq,
    )
    return pl.pallas_call(
        kernel,
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, head_dim), lambda i: (i, 0)),
            pl.BlockSpec(kv_cache.shape, lambda i: (0, 0, 0, 0)),
            pl.BlockSpec(block_list.shape, lambda i: (0,)),
            pl.BlockSpec(block_offsets.shape, lambda i: (0,)),
            pl.BlockSpec(seq_lens.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, head_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, head_dim), jnp.float32),
        interpret=True,
    )(q, kv_cache, block_list, block_offsets, seq_lens)


def paged_attention_multihead(q, kv_cache, block_list, block_offsets, seq_lens, block_size):
    """vmap over heads: q [heads, batch, d], kv [heads, 2, nb, bs, d]."""
    fn = functools.partial(paged_attention, block_size=block_size)
    return jax.vmap(fn, in_axes=(0, 0, None, None, None))(
        q, kv_cache, block_list, block_offsets, seq_lens
    )
