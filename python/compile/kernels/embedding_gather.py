"""BatchedTable embedding gather as a Pallas kernel (paper §4.1, Fig 14(b)).

The paper's TPC-C BatchedTable fuses every table's lookups into one kernel
launch, treating the stacked tables as one big table with per-table start
offsets. The Pallas re-expression: the grid spans (table, batch-chunk);
each program resolves `indices + table_offset` to global rows and copies
the rows from the (unblocked, HBM-resident) stacked table into its output
block — the dynamic `pl.load` plays the role of the TPC's
`v_f32_ld_tnsr` indexed vector loads, and the embedding dimension maps to
the 128-lane axis (the 256-byte-granularity best practice).

interpret=True: see stream_ops.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lookups handled per program instance (the "unroll factor" of Fig 14(a)).
_CHUNK = 4


def _gather_kernel(idx_ref, off_ref, tables_ref, o_ref, *, chunk):
    t = pl.program_id(0)
    c = pl.program_id(1)
    table_start = off_ref[t]
    for u in range(chunk):  # unrolled, like the TPC-C `#pragma unroll(4)`
        row = idx_ref[t, c * chunk + u] + table_start
        vec = pl.load(tables_ref, (pl.dslice(row, 1), slice(None)))
        o_ref[0, u, :] = vec[0, :]


def batched_embedding_gather(tables, indices, table_offsets):
    """Gather `indices` (+ per-table offsets) from the stacked `tables`.

    Args:
      tables: [total_rows, dim] float array (all tables stacked).
      indices: [n_tables, batch] int32 table-local row ids.
      table_offsets: [n_tables] int32 start row per table.

    Returns:
      [n_tables, batch, dim] gathered vectors.
    """
    n_tables, batch = indices.shape
    dim = tables.shape[1]
    assert batch % _CHUNK == 0, "batch must be a multiple of the chunk size"
    grid = (n_tables, batch // _CHUNK)
    kernel = functools.partial(_gather_kernel, chunk=_CHUNK)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(indices.shape, lambda t, c: (0, 0)),  # all indices
            pl.BlockSpec(table_offsets.shape, lambda t, c: (0,)),
            pl.BlockSpec(tables.shape, lambda t, c: (0, 0)),  # full table
        ],
        out_specs=pl.BlockSpec((1, _CHUNK, dim), lambda t, c: (t, c, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tables, batch, dim), tables.dtype),
        interpret=True,
    )(indices, table_offsets, tables)
    return out


def pooled_embedding_lookup(tables, indices, table_offsets):
    """Sum-pooled lookup: DLRM's embedding-bag (pooling over the lookup
    axis). indices: [n_tables, batch, pooling]."""
    n_tables, batch, pooling = indices.shape
    flat = indices.reshape(n_tables, batch * pooling)
    gathered = batched_embedding_gather(tables, flat, table_offsets)
    return gathered.reshape(n_tables, batch, pooling, -1).sum(axis=2)
