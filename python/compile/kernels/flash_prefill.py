"""Causal flash-attention prefill as a Pallas kernel.

The paper (§5) highlights that Gaudi's lack of low-level MME access blocks
FlashAttention-style fusion — this kernel is the TPU-shaped counterfactual:
one pass over K/V with an online softmax, blocks staged through VMEM, the
two matmuls (`q @ k^T`, `p @ v`) hitting the MXU on a real lowering.

Single-head kernel (callers vmap over heads); interpret=True as everywhere
in this repo.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_BIG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, seq_len):
    qi = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)  # [block_q, d]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    n_kblocks = seq_len // block_k

    def body(kj, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(kj * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(kj * block_k, block_k), slice(None)))
        s = (q @ k.astype(jnp.float32).T) * scale  # [bq, bk]
        k_pos = kj * block_k + jax.lax.iota(jnp.int32, block_k)
        causal = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(causal, s, _NEG_BIG)
        m_new = jnp.maximum(m, s.max(axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), _NEG_BIG, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_kblocks, body, (m0, l0, acc0))
    o_ref[...] = acc / jnp.maximum(l, 1e-30)[:, None]


def flash_prefill(q, k, v, block_q=16, block_k=16):
    """Causal attention over a full prompt, single head.

    Args:
      q, k, v: [seq, head_dim]; seq must divide by block_q and block_k.

    Returns:
      [seq, head_dim] float32 attention outputs.
    """
    seq, d = q.shape
    assert seq % block_q == 0 and seq % block_k == 0, "seq must tile evenly"
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_len=seq)
    return pl.pallas_call(
        kernel,
        grid=(seq // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((seq, d), lambda i: (0, 0)),
            pl.BlockSpec((seq, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((seq, d), jnp.float32),
        interpret=True,
    )(q, k, v)


def flash_prefill_multihead(q, k, v, block_q=16, block_k=16):
    """vmap over heads: q/k/v [heads, seq, d]."""
    fn = functools.partial(flash_prefill, block_q=block_q, block_k=block_k)
    return jax.vmap(fn)(q, k, v)
