"""STREAM microbenchmark kernels (ADD / SCALE / TRIAD) as Pallas kernels.

These are the Pallas re-expression of the paper's TPC-C STREAM kernels
(Algorithm 1 / Fig 2(c)). Hardware adaptation (DESIGN.md §Hardware-
Adaptation): the TPC's 256-byte access-granularity best practice becomes a
last-dimension block of 128 lanes; the manual 4x loop unroll that hides
the TPC's 4-cycle latency becomes a `grid` of row-blocks, each program
streaming an (8, 128) tile through VMEM.

All kernels run with `interpret=True`: the CPU PJRT client cannot execute
Mosaic custom-calls (real-TPU lowering), and correctness — checked against
`ref.py` — is the goal of this path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile shape: 8 sublanes x 128 lanes, the native float32 TPU tile.
_ROWS = 8
_LANES = 128
_TILE = _ROWS * _LANES


def _pad_to_tiles(x):
    """Pad a 1D array to a whole number of (8, 128) tiles; return the 2D
    view and the original length."""
    n = x.shape[0]
    padded = ((n + _TILE - 1) // _TILE) * _TILE
    x = jnp.pad(x, (0, padded - n))
    return x.reshape(-1, _LANES), n


def _tile_spec():
    return pl.BlockSpec((_ROWS, _LANES), lambda i: (i, 0))


def _run_elementwise(kernel, out_dtype, rows2d, *inputs):
    grid = (rows2d.shape[0] // _ROWS,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[_tile_spec() for _ in inputs],
        out_specs=_tile_spec(),
        out_shape=jax.ShapeDtypeStruct(rows2d.shape, out_dtype),
        interpret=True,
    )(*inputs)


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def _scale_kernel(a_ref, o_ref, *, scalar):
    o_ref[...] = scalar * a_ref[...]


def _triad_kernel(a_ref, b_ref, o_ref, *, scalar):
    # One fused multiply-add per lane — the MAC the TPC issues for TRIAD.
    o_ref[...] = scalar * a_ref[...] + b_ref[...]


def add(a, b):
    """STREAM ADD over 1D arrays of any length."""
    assert a.shape == b.shape and a.ndim == 1
    a2, n = _pad_to_tiles(a)
    b2, _ = _pad_to_tiles(b)
    out = _run_elementwise(_add_kernel, a2.dtype, a2, a2, b2)
    return out.reshape(-1)[:n]


def scale(a, scalar):
    """STREAM SCALE over a 1D array."""
    assert a.ndim == 1
    a2, n = _pad_to_tiles(a)
    kernel = functools.partial(_scale_kernel, scalar=scalar)
    out = _run_elementwise(kernel, a2.dtype, a2, a2)
    return out.reshape(-1)[:n]


def triad(a, b, scalar):
    """STREAM TRIAD over 1D arrays."""
    assert a.shape == b.shape and a.ndim == 1
    a2, n = _pad_to_tiles(a)
    b2, _ = _pad_to_tiles(b)
    kernel = functools.partial(_triad_kernel, scalar=scalar)
    out = _run_elementwise(kernel, a2.dtype, a2, a2, b2)
    return out.reshape(-1)[:n]
