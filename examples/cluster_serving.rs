//! Cluster serving example: a data-parallel fleet of simulated Llama-3.1-8B
//! engine replicas behind the admission router, serving an open-loop
//! Dynamic-Sonnet-like load. Shows the deployment-sizing story: offered
//! load fixed, replica count and route policy swept, fleet tail latency
//! and goodput-under-SLO reported.
//!
//! ```bash
//! cargo run --release --example cluster_serving
//! ```

use cuda_myth::config::{DeviceKind, ServingConfig};
use cuda_myth::models::llama::LlamaConfig;
use cuda_myth::serving::cluster::ClusterSim;
use cuda_myth::serving::qos::ClassSet;
use cuda_myth::serving::router::RoutePolicy;
use cuda_myth::workload::OpenLoopTrace;

const SLO_TTFT_S: f64 = 1.0;
const SLO_TPOT_S: f64 = 0.1;

fn slo_classes() -> ClassSet {
    ClassSet::scalar(SLO_TTFT_S, SLO_TPOT_S)
}

fn main() {
    let trace = OpenLoopTrace::new(24.0, 4.0);
    let requests = trace.generate(29);
    println!(
        "== open-loop load: {:.0} req/s for {:.0}s -> {} requests ==",
        trace.rate,
        trace.duration,
        requests.len()
    );
    println!(
        "{:8} {:13} {:9} {:>10} {:>12} {:>12} {:>14} {:>9}",
        "device", "policy", "replicas", "tok/s", "p99 TTFT ms", "p99 TPOT ms", "goodput req/s", "requeues"
    );
    for device in [DeviceKind::Gaudi2, DeviceKind::A100] {
        for policy in [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded] {
            for replicas in [1usize, 2, 4] {
                let cfg = ServingConfig {
                    device,
                    replicas,
                    route_policy: policy,
                    max_decode_batch: 32,
                    num_blocks: 8192,
                    ..Default::default()
                };
                let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
                sim.submit_all(requests.clone());
                let s = sim.run_to_completion();
                let goodput = sim.fleet_metrics().goodput(&slo_classes());
                println!(
                    "{:8} {:13} {:9} {:10.1} {:12.1} {:12.2} {:14.2} {:9}",
                    device.name(),
                    policy.name(),
                    replicas,
                    s.throughput_tps,
                    s.p99_ttft * 1e3,
                    s.p99_tpot * 1e3,
                    goodput,
                    sim.requeues,
                );
            }
        }
        println!();
    }
    // Heterogeneous fleets: mixed Gaudi-2/A100 replicas behind one
    // cost-aware prefix-affinity router, at one fixed offered load.
    println!("== mixed fleets (4 replicas, prefix-affinity router) ==");
    println!(
        "{:24} {:>10} {:>12} {:>14} {:>9}",
        "fleet", "tok/s", "p99 TTFT ms", "goodput req/s", "requeues"
    );
    let tagged = OpenLoopTrace::new(24.0, 4.0).with_prefix_groups(8).generate(29);
    for gaudi in (0..=4usize).rev() {
        let mut fleet = vec![DeviceKind::Gaudi2; gaudi];
        fleet.extend(vec![DeviceKind::A100; 4 - gaudi]);
        let label = format!("{}x Gaudi-2 + {}x A100", gaudi, 4 - gaudi);
        let cfg = ServingConfig {
            route_policy: RoutePolicy::PrefixAffinity,
            max_decode_batch: 32,
            num_blocks: 8192,
            ..Default::default()
        }
        .with_fleet(fleet);
        let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        sim.submit_all(tagged.clone());
        let s = sim.run_to_completion();
        let goodput = sim.fleet_metrics().goodput(&slo_classes());
        println!(
            "{:24} {:10.1} {:12.1} {:14.2} {:9}",
            label,
            s.throughput_tps,
            s.p99_ttft * 1e3,
            goodput,
            sim.requeues,
        );
    }
    println!();
    println!("Adding replicas trades fleet cost for tail latency until the SLO holds;");
    println!("`repro run cluster` derives the iso-SLO Gaudi-2 vs A100 sizing table and");
    println!("`repro run cluster-sweep` walks offered load across these fleet mixes to");
    println!("trace the goodput-under-SLO frontier curves.");
}
