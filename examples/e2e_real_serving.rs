//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §E2E): load the real
//! tiny-Llama decoder (AOT-compiled from JAX+Pallas to HLO), serve batched
//! requests through the Rust coordinator on the PJRT CPU client, and
//! report latency/throughput. Proves all three layers compose with real
//! numerics and Python nowhere on the request path.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_real_serving
//! ```

use cuda_myth::serving::real_engine::PjrtLlmEngine;
use cuda_myth::serving::request::Request;
use cuda_myth::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let t0 = std::time::Instant::now();
    let mut engine = PjrtLlmEngine::new(&dir)?;
    let dims = engine.dims();
    println!(
        "loaded + compiled artifacts in {:.2}s: {} slots, max_seq {}, prompt_pad {}, vocab {}",
        t0.elapsed().as_secs_f64(),
        dims.batch_slots,
        dims.max_seq,
        dims.prompt_pad,
        dims.vocab
    );

    // A batched workload: more requests than slots, mixed prompt and
    // output lengths, exercising slot recycling.
    let mut rng = Rng::new(123);
    let n_req = 16u64;
    let mut total_out = 0usize;
    for i in 0..n_req {
        let plen = rng.range(3, dims.prompt_pad as u64 / 2) as usize;
        let out = rng.range(4, 24) as usize;
        total_out += out;
        let prompt: Vec<i32> =
            (0..plen).map(|_| rng.below(dims.vocab as u64 / 2) as i32).collect();
        engine.submit(Request::new(i, plen, out, 0.0), prompt)?;
    }
    println!("submitted {n_req} requests ({total_out} output tokens requested)");

    let s = engine.run_to_completion()?;
    println!("\n== E2E real-numerics serving results ==");
    println!("requests completed : {}", s.requests);
    println!("decode steps       : {}", engine.steps());
    println!("tokens generated   : {}", engine.tokens_generated());
    println!("throughput         : {:.1} tok/s, {:.2} req/s", s.throughput_tps, s.throughput_rps);
    println!("mean TTFT          : {:.1} ms (p99 {:.1} ms)", s.mean_ttft * 1e3, s.p99_ttft * 1e3);
    println!("mean TPOT          : {:.1} ms (p99 {:.1} ms)", s.mean_tpot * 1e3, s.p99_tpot * 1e3);
    println!("mean E2E latency   : {:.1} ms", s.mean_e2e * 1e3);
    assert_eq!(s.requests as u64, n_req, "every request must finish");
    Ok(())
}
