//! LLM serving example: the vLLM-style engine (simulated backend) serving
//! Llama-3.1-8B on the Dynamic-Sonnet-like workload, comparing devices,
//! BlockTable vs BlockList layouts, and the max-decode-batch SLO knob
//! (paper Fig 12 / Fig 17(d,e)).

use cuda_myth::config::{DeviceKind, ServingConfig};
use cuda_myth::models::llama::LlamaConfig;
use cuda_myth::serving::engine::{Engine, SimBackend};
use cuda_myth::workload::DynamicSonnet;

fn serve(device: DeviceKind, use_block_list: bool, max_batch: usize) -> (f64, f64, f64) {
    let cfg = ServingConfig {
        device,
        use_block_list,
        max_decode_batch: max_batch,
        num_blocks: 8192,
        ..Default::default()
    };
    let backend = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
    let mut engine = Engine::new(cfg, backend);
    for r in DynamicSonnet::default().generate(96, f64::INFINITY, 11) {
        engine.submit(r);
    }
    let s = engine.run_to_completion();
    (s.throughput_tps, s.mean_ttft * 1e3, s.mean_tpot * 1e3)
}

fn main() {
    println!("== Llama-3.1-8B on the Dynamic-Sonnet-like workload (96 requests) ==\n");
    println!("{:8} {:10} {:6}  {:>12} {:>10} {:>10}", "device", "layout", "batch", "tok/s", "TTFT ms", "TPOT ms");
    for &mb in &[8usize, 32, 128] {
        for (device, layout, ubl) in [
            (DeviceKind::Gaudi2, "BlockList", true),
            (DeviceKind::Gaudi2, "BlockTable", false),
            (DeviceKind::A100, "fused", true),
        ] {
            let (tps, ttft, tpot) = serve(device, ubl, mb);
            println!(
                "{:8} {:10} {:6}  {:12.1} {:10.1} {:10.2}",
                device.name(),
                layout,
                mb,
                tps,
                ttft,
                tpot
            );
        }
        println!();
    }
    println!("BlockList (vLLM_opt) vs BlockTable (vLLM_base) is the paper's §4.2 case study;");
    println!("throughput rises with the batch knob while TTFT/TPOT degrade (Fig 17(d,e)).");
}
