//! Quickstart: the 60-second tour of the library.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use cuda_myth::config::DeviceKind;
use cuda_myth::sim::device::Device;
use cuda_myth::sim::collective::{self, Collective};
use cuda_myth::sim::Dtype;

fn main() {
    // 1. Run a GEMM on both simulated devices (paper Fig 4).
    let gaudi = Device::new(DeviceKind::Gaudi2);
    let a100 = Device::new(DeviceKind::A100);
    let (m, k, n) = (4096, 4096, 4096);
    let g = gaudi.gemm(m, k, n, Dtype::Bf16);
    let a = a100.gemm(m, k, n, Dtype::Bf16);
    println!("GEMM {m}x{k}x{n} BF16:");
    println!(
        "  Gaudi-2: {:6.1} TF ({:4.1}% util, MME geometry {})",
        g.achieved_flops / 1e12,
        100.0 * g.utilization,
        g.config
    );
    println!(
        "  A100:    {:6.1} TF ({:4.1}% util, CTA tile {})",
        a.achieved_flops / 1e12,
        100.0 * a.utilization,
        a.config
    );

    // 2. A random gather (paper Fig 9): the 256 B granularity cliff.
    for vec_bytes in [64.0, 256.0, 1024.0] {
        let gg = gaudi.gather(1e6, vec_bytes);
        let ga = a100.gather(1e6, vec_bytes);
        println!(
            "gather {vec_bytes:6}B vectors: Gaudi-2 {:4.1}% vs A100 {:4.1}% bandwidth util",
            100.0 * gg.utilization,
            100.0 * ga.utilization
        );
    }

    // 3. An AllReduce on both node fabrics (paper Fig 10).
    for n_dev in [2usize, 8] {
        let g = collective::run(DeviceKind::Gaudi2, Collective::AllReduce, n_dev, 32e6);
        let a = collective::run(DeviceKind::A100, Collective::AllReduce, n_dev, 32e6);
        println!(
            "allreduce 32MB x{n_dev} devices: Gaudi-2 {:4.1}% vs A100 {:4.1}% bus-bw util",
            100.0 * g.utilization,
            100.0 * a.utilization
        );
    }

    println!("\nNext: `repro list` and `repro run fig4 | fig17 | ...`");
}
