//! RecSys serving example (the paper's §3.5 / §4.1 workload): serve
//! DLRM-DCNv2 batches on both simulated devices with the Zipf-skewed
//! embedding workload, and — if `make artifacts` has run — execute the
//! real tiny-DLRM HLO artifact through PJRT on the same index stream.

use cuda_myth::config::DeviceKind;
use cuda_myth::models::dlrm::{self, DlrmConfig};
use cuda_myth::ops::embedding::{self, EmbeddingImpl, EmbeddingWork};
use cuda_myth::runtime::{HostTensor, Runtime};
use cuda_myth::sim::Dtype;
use cuda_myth::workload::EmbeddingTrace;

fn main() -> anyhow::Result<()> {
    // Simulated end-to-end serving comparison (Fig 11).
    println!("== simulated DLRM serving (batch 4096, dim 128) ==");
    for cfg in [DlrmConfig::rm1(), DlrmConfig::rm2()] {
        let g = dlrm::serve(&cfg, DeviceKind::Gaudi2, 4096, 128);
        let a = dlrm::serve(&cfg, DeviceKind::A100, 4096, 128);
        println!(
            "{}: Gaudi-2 {:8.0} samples/s @ {:3.0} W | A100 {:8.0} samples/s @ {:3.0} W | speedup {:.2}x",
            cfg.name,
            g.throughput(4096),
            g.avg_power,
            a.throughput(4096),
            a.avg_power,
            a.time / g.time
        );
    }

    // Operator-level study (Fig 15) on a Zipf-skewed index stream.
    println!("\n== embedding operators (RM2 config, batch 4096, 512 B vectors) ==");
    let work = EmbeddingWork { tables: 20, batch: 4096, pooling: 1, vec_bytes: 512.0 };
    for imp in [
        EmbeddingImpl::GaudiSdkSingleTable,
        EmbeddingImpl::GaudiSingleTable,
        EmbeddingImpl::GaudiBatchedTable,
        EmbeddingImpl::A100Fbgemm,
    ] {
        let r = embedding::run(imp, work, Dtype::Fp32);
        println!(
            "{:18} {:8.1} us  {:5.1}% bandwidth util  ({} launches)",
            imp.name(),
            r.time * 1e6,
            100.0 * r.bandwidth_utilization,
            r.kernel_launches
        );
    }

    // Real-numerics path: tiny-DLRM artifact + Zipf indices through PJRT.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\n== REAL tiny-DLRM inference through PJRT ==");
        let mut rt = Runtime::new("artifacts")?;
        let weights = {
            let init = rt.load("init_dlrm_weights")?;
            init.run(&[])?.remove(0)
        };
        let exe = rt.load("dlrm_forward")?;
        let batch = exe.entry.inputs[1].shape[0];
        let dense_in = exe.entry.inputs[1].shape[1];
        let tables = exe.entry.meta["tables"] as usize;
        let pooling = exe.entry.meta["pooling"] as usize;
        let rows = exe.entry.meta["rows_per_table"] as usize;
        let mut trace = EmbeddingTrace::new(tables, rows, 1.1, 42);
        let t0 = std::time::Instant::now();
        let n_batches = 5;
        let mut checksum = 0.0f32;
        for _ in 0..n_batches {
            let idx: Vec<i32> =
                trace.batch(batch, pooling).into_iter().map(|x| x as i32).collect();
            let dense: Vec<f32> = (0..batch * dense_in).map(|i| (i % 5) as f32 * 0.2).collect();
            let out = exe.run(&[
                weights.clone(),
                HostTensor::F32(dense),
                HostTensor::I32(idx),
            ])?;
            checksum += out[0].as_f32()?.iter().sum::<f32>();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{} batches x {} samples in {:.1} ms -> {:.0} samples/s (checksum {:.3})",
            n_batches,
            batch,
            dt * 1e3,
            (n_batches * batch) as f64 / dt,
            checksum
        );
    } else {
        println!("\n(run `make artifacts` to also exercise the real PJRT path)");
    }
    Ok(())
}
